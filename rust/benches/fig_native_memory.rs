//! Native reproduction of the paper's naive-vs-MixFlow memory gap
//! (Figures 1/4 shape) — no artifacts, no PJRT, no Python.
//!
//! For each configuration — the hyper-LR MLP task with a plain-SGD inner
//! loop, the single-head attention + layernorm task driven by an Adam
//! inner optimiser, and the **multi-head batched** attention workload
//! (the shape-for-shape match of the paper's benchmark setting) — and
//! each unroll length T, computes the hypergradient five ways: naive
//! reverse-over-reverse on one monolithic tape, MixFlow-MG with full
//! checkpointing, MixFlow-MG under `CheckpointPolicy::Auto` (K ≈ √T),
//! truncated back-propagation (`truncated:4` — the mixflow window
//! confined to the last 4 inner steps), and the EvoGrad population
//! estimate (no checkpoints at all), reporting live tape bytes plus the
//! **KV-reuse analysis**: peak live K/V-projection bytes per path, and
//! the backward-sweep K/V rebuilds split into checkpoint-alias vs remat
//! bytes.  All five paths run on ONE persistent [`HypergradEngine`]
//! each, reused across the whole unroll ladder.  Also cross-checks the
//! paths agree numerically — including the truncated window's exactness
//! contract: at `T ≤ horizon` it must be bit-for-bit mixflow, and at
//! `T ≥ 8` (attention + Adam) its peak bytes must sit strictly below
//! full mixflow — and (when an artifact manifest is discoverable)
//! prints the `hlo::memory` simulator's default/mixflow ratios next to
//! the native ones so the simulator's trend has a ground-truth oracle.
//!
//! The engines run with telemetry on: every rung conformance-checks the
//! strategy's own `MemoryReport.arena_allocs/arena_reuses` against the
//! registry's independently mirrored `arena.allocs`/`arena.reuses`
//! deltas in the step trace (the two ledgers are written by different
//! code paths, so drift means an accounting bug), and the collected
//! traces land in `TRACE_native_memory.jsonl` +
//! `TRACE_native_memory_chrome.json`.
//!
//! ```bash
//! cargo run --release --bin fig_native_memory
//! ```

use mixflow::autodiff::engine::{HypergradEngine, HypergradMode};
use mixflow::autodiff::mixflow::{
    rel_err, BilevelProblem, CheckpointPolicy, Hypergrad,
};
use mixflow::autodiff::optim::InnerOptimiser;
use mixflow::autodiff::problems::{
    AttentionProblem, HyperLrProblem, MultiHeadAttentionProblem,
};
use mixflow::obs::{write_trace, StepTrace, TraceFormat};
use mixflow::util::stats::human_bytes;
use mixflow::util::table::Table;

/// Truncation window for the `truncated` ladder column: full-window
/// (≡ mixflow, bit-for-bit) on the T ∈ {2, 4} rungs, a proper
/// truncation on T ∈ {8, 16} where the peak-memory gate applies.
const TRUNC_HORIZON: usize = 4;

type ProblemBuilder = fn(usize) -> Box<dyn BilevelProblem>;

fn build_hyperlr_sgd(unroll: usize) -> Box<dyn BilevelProblem> {
    Box::new(HyperLrProblem::with_unroll(1, unroll))
}

fn build_attention_adam(unroll: usize) -> Box<dyn BilevelProblem> {
    Box::new(
        AttentionProblem::with_unroll(1, unroll)
            .with_optimiser(InnerOptimiser::adam()),
    )
}

fn build_multihead_attention_adam(unroll: usize) -> Box<dyn BilevelProblem> {
    // The canonical multi-head default (2 heads × head dim 3 over
    // 2-sequence batches) — the same shape the KV-counter integration
    // tests pin, so bench and tests cannot drift apart.
    Box::new(
        MultiHeadAttentionProblem::with_unroll(1, unroll)
            .with_optimiser(InnerOptimiser::adam()),
    )
}

/// Registry-vs-`MemoryReport` conformance: the engine mirrors arena
/// take/alloc deltas into the registry independently of the strategy's
/// own bookkeeping, and the step trace carries both ledgers — any
/// disagreement is an accounting bug, not noise.
fn check_trace_conformance(
    label: &str,
    unroll: usize,
    variant: &str,
    trace: Option<&StepTrace>,
    h: &Hypergrad,
) -> bool {
    let Some(tr) = trace else {
        eprintln!(
            "FAIL {label} T={unroll} {variant}: telemetry on but no step \
             trace recorded"
        );
        return false;
    };
    let mut ok = true;
    for (counter, want) in [
        ("arena.allocs", h.memory.arena_allocs as u64),
        ("arena.reuses", h.memory.arena_reuses as u64),
    ] {
        let got = tr.counter(counter).unwrap_or(0);
        if got != want {
            eprintln!(
                "FAIL {label} T={unroll} {variant}: registry {counter} = \
                 {got} but MemoryReport says {want}"
            );
            ok = false;
        }
    }
    ok
}

/// One naive vs MixFlow(full) vs MixFlow(auto-remat) table over the
/// unroll ladder; false if the memory gap, a KV-reuse counter
/// (`check_kv` configs only), a registry conformance check or the
/// numeric agreement breaks anywhere.  Drains each engine's step traces
/// into `cells` under `slug/{variant}` labels.
fn run_config(
    label: &str,
    slug: &str,
    build: ProblemBuilder,
    check_kv: bool,
    cells: &mut Vec<(String, Vec<StepTrace>)>,
) -> bool {
    println!("\n[{label}]");
    let unrolls = [2usize, 4, 8, 16];
    let mut t = Table::new(&[
        "unroll T",
        "naive bytes",
        "mixflow tape",
        "mixflow ckpt",
        "ratio",
        "trunc4 peak",
        "evograd peak",
        "naive KV",
        "mix KV peak",
        "KV ckpt-alias",
        "KV remat (auto)",
        "max |dEta diff|",
    ])
    .numeric_cols(&[0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]);

    // One persistent engine per path, shared by the whole ladder: rungs
    // after the first draw their step tapes out of the warm arena.
    let mut naive_engine = HypergradEngine::builder()
        .mode(HypergradMode::Naive)
        .telemetry(true)
        .build();
    let mut mixflow_engine =
        HypergradEngine::builder().telemetry(true).build();
    let mut auto_engine = HypergradEngine::builder()
        .checkpoint(CheckpointPolicy::Auto)
        .telemetry(true)
        .build();
    let mut trunc_engine = HypergradEngine::builder()
        .mode(HypergradMode::Truncated { horizon: TRUNC_HORIZON })
        .telemetry(true)
        .build();
    let mut evo_engine = HypergradEngine::builder()
        .mode(HypergradMode::Evograd)
        .telemetry(true)
        .build();

    let mut ok = true;
    for &unroll in &unrolls {
        let problem = build(unroll);
        let theta0 = problem.theta0();
        let eta = problem.eta0();
        let naive = naive_engine.run(problem.as_ref(), &theta0, &eta);
        let mixed = mixflow_engine.run(problem.as_ref(), &theta0, &eta);
        let auto = auto_engine.run(problem.as_ref(), &theta0, &eta);
        let trunc = trunc_engine.run(problem.as_ref(), &theta0, &eta);
        let evo = evo_engine.run(problem.as_ref(), &theta0, &eta);
        for (variant, trace, h) in [
            ("naive", naive_engine.last_trace(), &naive),
            ("mixflow", mixflow_engine.last_trace(), &mixed),
            ("mixflow-auto", auto_engine.last_trace(), &auto),
            ("truncated4", trunc_engine.last_trace(), &trunc),
            ("evograd", evo_engine.last_trace(), &evo),
        ] {
            if !check_trace_conformance(label, unroll, variant, trace, h) {
                ok = false;
            }
        }
        // Truncation contract, both directions of the frontier: a
        // full-width window is not an approximation (bit-for-bit
        // mixflow), and a proper truncation must actually buy memory.
        if unroll <= TRUNC_HORIZON {
            let diff = mixed
                .d_eta
                .iter()
                .zip(trunc.d_eta.iter())
                .map(|(a, b)| a.max_abs_diff(b))
                .fold(0.0f64, f64::max);
            if diff != 0.0 {
                eprintln!(
                    "FAIL {label} T={unroll}: truncated horizon \
                     {TRUNC_HORIZON} >= T must be bit-for-bit mixflow, \
                     diff {diff:.3e}"
                );
                ok = false;
            }
        } else {
            if trunc.memory.checkpoint_bytes >= mixed.memory.checkpoint_bytes
            {
                eprintln!(
                    "FAIL {label} T={unroll}: truncated checkpoints {} not \
                     below full mixflow {}",
                    trunc.memory.checkpoint_bytes,
                    mixed.memory.checkpoint_bytes
                );
                ok = false;
            }
            // The headline acceptance: on the attention + Adam configs
            // the truncated window's peak must sit strictly below full
            // mixflow once the horizon is a proper subset of T.
            if check_kv && trunc.memory.peak_bytes >= mixed.memory.peak_bytes
            {
                eprintln!(
                    "FAIL {label} T={unroll}: truncated peak {} not below \
                     full mixflow {}",
                    trunc.memory.peak_bytes, mixed.memory.peak_bytes
                );
                ok = false;
            }
        }
        // EvoGrad stores nothing across steps: no checkpoints ever, and
        // a finite estimate (its accuracy is gated statistically in the
        // strategies integration suite, not here).
        if evo.memory.checkpoint_bytes != 0 {
            eprintln!(
                "FAIL {label} T={unroll}: evograd checkpointed {} bytes",
                evo.memory.checkpoint_bytes
            );
            ok = false;
        }
        if !evo.outer_loss.is_finite()
            || evo
                .d_eta
                .iter()
                .any(|g| g.data.iter().any(|v| !v.is_finite()))
        {
            eprintln!("FAIL {label} T={unroll}: evograd went non-finite");
            ok = false;
        }
        let err = rel_err(&naive.d_eta, &mixed.d_eta);
        let naive_bytes = naive.memory.total_bytes();
        let mixed_bytes = mixed.memory.total_bytes();
        if unroll >= 4 && mixed_bytes >= naive_bytes {
            eprintln!("FAIL {label} T={unroll}: total bytes gap inverted");
            ok = false;
        }
        if unroll >= 4 && mixed.memory.peak_bytes >= naive.memory.peak_bytes {
            eprintln!(
                "FAIL {label} T={unroll}: mixflow peak {} not below naive {}",
                mixed.memory.peak_bytes, naive.memory.peak_bytes
            );
            ok = false;
        }
        // Same bound the naive≈mixflow property test enforces; the two
        // paths order f64 ops differently, so exact agreement is
        // platform-dependent.
        if err > 1e-6 {
            eprintln!("FAIL {label} T={unroll}: naive vs mixflow {err:.2e}");
            ok = false;
        }
        // Auto remat replays the identical op sequence from the stored
        // checkpoints — it must reproduce full checkpointing to 1e-12.
        if rel_err(&mixed.d_eta, &auto.d_eta) > 1e-12 {
            eprintln!("FAIL {label} T={unroll}: auto remat drifted from full");
            ok = false;
        }
        if check_kv && unroll >= 4 {
            // The KV-reuse acceptance: K/V projections are tagged, the
            // naive tape keeps all T steps' worth live while mixflow
            // holds one step's worth, every backward step under full
            // checkpointing rebuilds K/V from a checkpoint alias, and
            // auto remat (K ≥ 2 at T ≥ 4) rematerialises some of it.
            if naive.memory.kv_peak_bytes == 0
                || mixed.memory.kv_peak_bytes == 0
                || mixed.memory.kv_ckpt_alias_bytes == 0
                || auto.memory.kv_remat_bytes == 0
            {
                eprintln!(
                    "FAIL {label} T={unroll}: KV-reuse counters must be \
                     nonzero (naive kv {}, mix kv {}, alias {}, remat {})",
                    naive.memory.kv_peak_bytes,
                    mixed.memory.kv_peak_bytes,
                    mixed.memory.kv_ckpt_alias_bytes,
                    auto.memory.kv_remat_bytes
                );
                ok = false;
            }
            if mixed.memory.kv_peak_bytes >= naive.memory.kv_peak_bytes {
                eprintln!(
                    "FAIL {label} T={unroll}: mixflow KV peak {} not below \
                     naive {}",
                    mixed.memory.kv_peak_bytes, naive.memory.kv_peak_bytes
                );
                ok = false;
            }
        }
        t.row(vec![
            unroll.to_string(),
            human_bytes(naive_bytes as u64),
            human_bytes(mixed.memory.tape_bytes as u64),
            human_bytes(mixed.memory.checkpoint_bytes as u64),
            format!("{:.2}", naive_bytes as f64 / mixed_bytes.max(1) as f64),
            human_bytes(trunc.memory.peak_bytes as u64),
            human_bytes(evo.memory.peak_bytes as u64),
            human_bytes(naive.memory.kv_peak_bytes as u64),
            human_bytes(mixed.memory.kv_peak_bytes as u64),
            human_bytes(mixed.memory.kv_ckpt_alias_bytes as u64),
            human_bytes(auto.memory.kv_remat_bytes as u64),
            format!("{err:.2e}"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "  (persistent engines: naive ran {} ladder rungs on one tape, \
         mixflow {}, auto-remat {}, truncated{TRUNC_HORIZON} {}, \
         evograd {})",
        naive_engine.outer_steps(),
        mixflow_engine.outer_steps(),
        auto_engine.outer_steps(),
        trunc_engine.outer_steps(),
        evo_engine.outer_steps()
    );
    cells.push((format!("{slug}/naive"), naive_engine.take_step_traces()));
    cells
        .push((format!("{slug}/mixflow"), mixflow_engine.take_step_traces()));
    cells.push((
        format!("{slug}/mixflow-auto"),
        auto_engine.take_step_traces(),
    ));
    cells.push((
        format!("{slug}/truncated{TRUNC_HORIZON}"),
        trunc_engine.take_step_traces(),
    ));
    cells.push((format!("{slug}/evograd"), evo_engine.take_step_traces()));
    ok
}

fn main() {
    println!(
        "Figure (native) — tape memory: reverse-over-reverse vs MixFlow-MG"
    );
    let configs: [(&str, &str, ProblemBuilder, bool); 3] = [
        (
            "hyperlr · sgd inner optimiser",
            "hyperlr",
            build_hyperlr_sgd,
            false,
        ),
        (
            "attention+layernorm · adam inner optimiser",
            "attention",
            build_attention_adam,
            true,
        ),
        (
            "multi-head attention (2 heads × 2 seqs) · adam inner optimiser",
            "attention_mh2b2",
            build_multihead_attention_adam,
            true,
        ),
    ];
    let mut all_ok = true;
    let mut trace_cells: Vec<(String, Vec<StepTrace>)> = Vec::new();
    for (label, slug, build, check_kv) in configs {
        if !run_config(label, slug, build, check_kv, &mut trace_cells) {
            all_ok = false;
        }
    }
    for (tpath, format) in [
        ("TRACE_native_memory.jsonl", TraceFormat::Jsonl),
        ("TRACE_native_memory_chrome.json", TraceFormat::Chrome),
    ] {
        if let Err(e) = write_trace(tpath, format, &trace_cells) {
            eprintln!("FAIL: could not write {tpath}: {e}");
            all_ok = false;
        }
    }
    println!(
        "paper shape: the naive tape grows ~linearly in T while MixFlow-MG \
         holds one step's tape + O(T) checkpoints (θ plus optimiser \
         moments) — the ratio widens with T on all configurations, and on \
         the attention workloads the KV columns show the K/V projections \
         specifically moving from live-on-tape (naive) to \
         rebuilt-per-step from checkpoint aliases or remat (mixflow). \
         The trunc4/evograd columns chart the bias-for-memory frontier: \
         the truncated window caps checkpoint growth at the horizon, and \
         evograd holds no checkpoints at all."
    );

    // Cross-check against the HLO buffer-liveness simulator when real
    // artifacts are available (skipped gracefully otherwise).
    match mixflow::runtime::Manifest::discover() {
        Ok(manifest) => {
            use mixflow::coordinator::runner::{analyze_artifact, pair_ratios};
            let metas = manifest.group("fig4_sweep");
            let measurements: Vec<_> = metas
                .iter()
                .filter_map(|m| analyze_artifact(&manifest, m, "fig4").ok())
                .collect();
            let pairs = pair_ratios(&measurements);
            if pairs.is_empty() {
                println!("\n(hlo simulator cross-check: no fig4 pairs)");
            } else {
                let mut agree = 0;
                for p in &pairs {
                    if p.dynamic_ratio > 1.0 {
                        agree += 1;
                    }
                }
                println!(
                    "\nhlo::memory simulator cross-check: {agree}/{} \
                     artifact pairs show default > mixflow dynamic memory — \
                     same direction as the native tape counter above.",
                    pairs.len()
                );
            }
        }
        Err(_) => {
            println!(
                "\n(hlo simulator cross-check skipped: no artifact manifest \
                 — the native figure above needs none)"
            );
        }
    }

    if !all_ok {
        eprintln!("FAIL: mixflow did not beat naive on memory or diverged");
        std::process::exit(1);
    }
    println!(
        "fig_native_memory OK (TRACE_native_memory.jsonl, \
         TRACE_native_memory_chrome.json written)"
    );
}

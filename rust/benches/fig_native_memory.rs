//! Native reproduction of the paper's naive-vs-MixFlow memory gap
//! (Figures 1/4 shape) — no artifacts, no PJRT, no Python.
//!
//! For each configuration — the hyper-LR MLP task with a plain-SGD inner
//! loop, and the attention + layernorm task driven by an Adam inner
//! optimiser (the setup the paper actually benchmarks) — and each unroll
//! length T, computes the hypergradient twice: reverse-over-reverse on
//! one monolithic tape vs MixFlow-MG forward-over-reverse with per-step
//! tape reuse, and reports the live tape bytes each path needs.  Both
//! paths run on ONE persistent [`HypergradEngine`] each, reused across
//! the whole unroll ladder — the configuration every driver now shares.
//! Also cross-checks the two paths agree numerically, and (when an
//! artifact manifest is discoverable) prints the `hlo::memory`
//! simulator's default/mixflow ratios next to the native ones so the
//! simulator's trend has a ground-truth oracle.
//!
//! ```bash
//! cargo run --release --bin fig_native_memory
//! ```

use mixflow::autodiff::engine::{HypergradEngine, HypergradMode};
use mixflow::autodiff::mixflow::{rel_err, BilevelProblem};
use mixflow::autodiff::optim::InnerOptimiser;
use mixflow::autodiff::problems::{AttentionProblem, HyperLrProblem};
use mixflow::util::stats::human_bytes;
use mixflow::util::table::Table;

type ProblemBuilder = fn(usize) -> Box<dyn BilevelProblem>;

fn build_hyperlr_sgd(unroll: usize) -> Box<dyn BilevelProblem> {
    Box::new(HyperLrProblem::with_unroll(1, unroll))
}

fn build_attention_adam(unroll: usize) -> Box<dyn BilevelProblem> {
    Box::new(
        AttentionProblem::with_unroll(1, unroll)
            .with_optimiser(InnerOptimiser::adam()),
    )
}

/// One naive-vs-MixFlow table over the unroll ladder; false if the
/// memory gap or the numeric agreement breaks anywhere.
fn run_config(label: &str, build: ProblemBuilder) -> bool {
    println!("\n[{label}]");
    let unrolls = [2usize, 4, 8, 16];
    let mut t = Table::new(&[
        "unroll T",
        "naive tape",
        "mixflow tape",
        "mixflow ckpt",
        "ratio",
        "max |dEta diff|",
    ])
    .numeric_cols(&[0, 1, 2, 3, 4, 5]);

    // One persistent engine per path, shared by the whole ladder: rungs
    // after the first draw their step tapes out of the warm arena.
    let mut naive_engine =
        HypergradEngine::builder().mode(HypergradMode::Naive).build();
    let mut mixflow_engine = HypergradEngine::builder().build();

    let mut ok = true;
    for &unroll in &unrolls {
        let problem = build(unroll);
        let theta0 = problem.theta0();
        let eta = problem.eta0();
        let naive = naive_engine.run(problem.as_ref(), &theta0, &eta);
        let mixed = mixflow_engine.run(problem.as_ref(), &theta0, &eta);
        let err = rel_err(&naive.d_eta, &mixed.d_eta);
        let naive_bytes = naive.memory.total_bytes();
        let mixed_bytes = mixed.memory.total_bytes();
        if unroll >= 4 && mixed_bytes >= naive_bytes {
            ok = false;
        }
        // Same bound the naive≈mixflow property test enforces; the two
        // paths order f64 ops differently, so exact agreement is
        // platform-dependent.
        if err > 1e-6 {
            ok = false;
        }
        t.row(vec![
            unroll.to_string(),
            human_bytes(naive_bytes as u64),
            human_bytes(mixed.memory.tape_bytes as u64),
            human_bytes(mixed.memory.checkpoint_bytes as u64),
            format!("{:.2}", naive_bytes as f64 / mixed_bytes.max(1) as f64),
            format!("{err:.2e}"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "  (persistent engines: naive ran {} ladder rungs on one tape, \
         mixflow {})",
        naive_engine.outer_steps(),
        mixflow_engine.outer_steps()
    );
    ok
}

fn main() {
    println!(
        "Figure (native) — tape memory: reverse-over-reverse vs MixFlow-MG"
    );
    let configs: [(&str, ProblemBuilder); 2] = [
        ("hyperlr · sgd inner optimiser", build_hyperlr_sgd),
        ("attention+layernorm · adam inner optimiser", build_attention_adam),
    ];
    let mut all_ok = true;
    for (label, build) in configs {
        if !run_config(label, build) {
            all_ok = false;
        }
    }
    println!(
        "paper shape: the naive tape grows ~linearly in T while MixFlow-MG \
         holds one step's tape + O(T) checkpoints (θ plus optimiser \
         moments) — the ratio widens with T on both configurations."
    );

    // Cross-check against the HLO buffer-liveness simulator when real
    // artifacts are available (skipped gracefully otherwise).
    match mixflow::runtime::Manifest::discover() {
        Ok(manifest) => {
            use mixflow::coordinator::runner::{analyze_artifact, pair_ratios};
            let metas = manifest.group("fig4_sweep");
            let measurements: Vec<_> = metas
                .iter()
                .filter_map(|m| analyze_artifact(&manifest, m, "fig4").ok())
                .collect();
            let pairs = pair_ratios(&measurements);
            if pairs.is_empty() {
                println!("\n(hlo simulator cross-check: no fig4 pairs)");
            } else {
                let mut agree = 0;
                for p in &pairs {
                    if p.dynamic_ratio > 1.0 {
                        agree += 1;
                    }
                }
                println!(
                    "\nhlo::memory simulator cross-check: {agree}/{} \
                     artifact pairs show default > mixflow dynamic memory — \
                     same direction as the native tape counter above.",
                    pairs.len()
                );
            }
        }
        Err(_) => {
            println!(
                "\n(hlo simulator cross-check skipped: no artifact manifest \
                 — the native figure above needs none)"
            );
        }
    }

    if !all_ok {
        eprintln!("FAIL: mixflow did not beat naive on memory or diverged");
        std::process::exit(1);
    }
    println!("fig_native_memory OK");
}

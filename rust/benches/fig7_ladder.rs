//! Figure 7 (+ Table 6) — the Chinchilla scaling ladder: peak-dynamic-HBM
//! gain vs model size (B=4, T=2, MAML).  Analysis tier; uses the threaded
//! memory-aware scheduler since ladder HLO files are 8 MB+ each.
//!
//! Paper shape: gains grow with model size (10-25x at the top of the
//! paper's ladder).

use mixflow::coordinator::runner::{analyze_artifact, pair_ratios};
use mixflow::coordinator::scheduler::{run_pool, Job};
use mixflow::coordinator::{Measurement, ResultsStore};
use mixflow::runtime::Manifest;
use mixflow::util::bench::Bench;
use mixflow::util::table::{ratio_cell, Table};

fn main() {
    let manifest = Manifest::discover().expect("run make artifacts");
    let mut bench = Bench::new("fig7_ladder").with_iters(0, 1);

    // Fan analysis out over the scheduler (1 worker/core, 256 MiB of
    // resident HLO text admitted at a time).
    let metas: Vec<_> =
        manifest.group("fig7_ladder").into_iter().cloned().collect();
    let mut measurements: Vec<Measurement> = Vec::new();
    bench.run("ladder analysis via scheduler", || {
        let jobs: Vec<Job<Option<Measurement>>> = metas
            .iter()
            .map(|meta| {
                let meta = meta.clone();
                let manifest = manifest.clone();
                let size = std::fs::metadata(manifest.hlo_path(&meta))
                    .map(|m| m.len())
                    .unwrap_or(1 << 20);
                Job {
                    name: meta.key.clone(),
                    // Parsing + liveness costs ~20x the text size.
                    cost_bytes: size * 20,
                    work: Box::new(move || {
                        analyze_artifact(&manifest, &meta, "fig7_ladder").ok()
                    }),
                }
            })
            .collect();
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        measurements = run_pool(jobs, workers, 256 << 20)
            .into_iter()
            .filter_map(|(_, m)| m)
            .collect();
    });

    let store = ResultsStore::discover().expect("results dir");
    for m in &measurements {
        store.append("fig7_ladder", m).ok();
    }

    let mut pairs = pair_ratios(&measurements);
    pairs.sort_by_key(|p| p.param_count);
    println!("\nFigure 7 — Chinchilla scaling ladder: dynamic-HBM gain vs size");
    let mut t = Table::new(&[
        "model", "params", "layers", "dyn HBM gain", "total HBM gain",
    ])
    .numeric_cols(&[1, 2, 3, 4]);
    for p in &pairs {
        t.row(vec![
            p.size_name.clone(),
            p.param_count.to_string(),
            p.n_layers.to_string(),
            ratio_cell(p.dynamic_ratio),
            format!("{:.2}x", p.total_ratio),
        ]);
    }
    println!("{}", t.render());
    if pairs.len() >= 2 {
        let first = pairs.first().unwrap().dynamic_ratio;
        let last = pairs.last().unwrap().dynamic_ratio;
        println!(
            "gain trend: {:.2}x at {} → {:.2}x at {} (paper: grows with scale)",
            first,
            pairs.first().unwrap().size_name,
            last,
            pairs.last().unwrap().size_name
        );
    }
    bench.report();
}

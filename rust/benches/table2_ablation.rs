//! Table 2 (+ Fig. 3/10) — the §4 ablation cube on the 489M-scaled model.
//! Analysis tier: simulated memory only (the paper itself reports N/A step
//! times for most of these rows — they OOM'd on single devices).

use mixflow::coordinator::report::ablation_table;
use mixflow::coordinator::runner::{ExperimentRunner, RunOptions};
use mixflow::coordinator::ResultsStore;
use mixflow::runtime::Runtime;
use mixflow::util::bench::Bench;
use mixflow::util::stats::human_bytes;

fn main() {
    let runtime = Runtime::new().expect("run make artifacts");
    let mut bench = Bench::new("table2_ablation").with_iters(0, 1);
    let runner = ExperimentRunner::new(
        &runtime,
        RunOptions { timing_iters: 0, execute: false, seed: 0 },
    );

    let mut measurements = Vec::new();
    bench.run("analyse 8-combo cube (489M-scaled)", || {
        measurements = runner.run_group("table2_ablation");
    });
    let store = ResultsStore::discover().expect("results dir");
    for m in &measurements {
        store.append("table2_ablation", m).ok();
    }

    let mut rows: Vec<(String, &mixflow::coordinator::Measurement)> =
        measurements.iter().map(|m| (m.variant.clone(), m)).collect();
    rows.sort_by(|a, b| a.0.cmp(&b.0));
    println!(
        "{}",
        ablation_table(
            "Table 2 — 489M-scaled transformer ablation (paper Table 2)",
            &rows
        )
    );

    // Fig. 3: per-optimisation stage reduction.
    let find = |mode: &str, br: bool, sg: bool| {
        measurements.iter().find(|m| {
            m.variant == format!("{mode}_br{}_sg{}", br as u8, sg as u8)
        })
    };
    if let (Some(none), Some(br), Some(brsg), Some(full)) = (
        find("default", false, false),
        find("default", true, false),
        find("fwdrev", true, false),
        find("fwdrev", true, true),
    ) {
        println!("Figure 3 — HBM after each optimisation stage:");
        for (label, m) in [
            ("no optimisations", none),
            ("1 block remat", br),
            ("3 + mixed mode", brsg),
            ("2 + save inner grads (full MixFlow-MG)", full),
        ] {
            println!(
                "  {label:42} peak dynamic {}",
                human_bytes(m.sim_dynamic_bytes)
            );
        }
    }
    bench.report();
}

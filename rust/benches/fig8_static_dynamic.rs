//! Figure 8 — static vs dynamic memory decomposition across the ladder:
//! (a) per-rung static/dynamic split, (b) dynamic/static ratio shrinking
//! with scale, (c) total-HBM gain (4-6x when static dominates).

use std::collections::HashMap;

use mixflow::coordinator::report::static_dynamic_table;
use mixflow::coordinator::runner::{ExperimentRunner, RunOptions};
use mixflow::coordinator::{Measurement, ResultsStore};
use mixflow::runtime::Runtime;
use mixflow::util::bench::Bench;

fn main() {
    let runtime = Runtime::new().expect("run make artifacts");
    let mut bench = Bench::new("fig8_static_dynamic").with_iters(0, 1);
    let runner = ExperimentRunner::new(
        &runtime,
        RunOptions { timing_iters: 0, execute: false, seed: 0 },
    );

    // Reuse stored fig7 measurements when available (they're the same
    // artifacts); otherwise run the analysis now.
    let store = ResultsStore::discover().expect("results dir");
    let mut measurements =
        store.load_latest("fig7_ladder").unwrap_or_default();
    if measurements.is_empty() {
        bench.run("ladder analysis", || {
            measurements = runner.run_group("fig7_ladder");
        });
        for m in &measurements {
            store.append("fig7_ladder", m).ok();
        }
    } else {
        println!("(reusing stored fig7_ladder results)");
    }

    let mut by_size: HashMap<String, (Option<Measurement>, Option<Measurement>)> =
        HashMap::new();
    for m in measurements {
        let slot = by_size.entry(m.size_name.clone()).or_default();
        match m.variant.as_str() {
            "default" => slot.0 = Some(m),
            "mixflow" => slot.1 = Some(m),
            _ => {}
        }
    }
    let mut rows_owned: Vec<(String, Measurement, Measurement)> = by_size
        .into_iter()
        .filter_map(|(k, (d, x))| Some((k, d?, x?)))
        .collect();
    rows_owned.sort_by_key(|(_, d, _)| d.param_count);
    let rows: Vec<(String, &Measurement, &Measurement)> = rows_owned
        .iter()
        .map(|(k, d, x)| (k.clone(), d, x))
        .collect();
    println!("{}", static_dynamic_table(&rows));
    println!("paper shape: MixFlow-MG turns static memory into the dominant");
    println!("term; dynamic/static shrinks with scale; total gain 4-6x");
    println!("(recoverable to the full 10-25x with FSDP/reversible-update");
    println!("static-memory techniques, Appendix A.2).");
    bench.report();
}

//! Figure 6 (+ Table 5) — transformer-component sweeps: peak-dynamic-HBM
//! ratio while scaling d_model, ffw_size, n_heads, n_layers one at a time.
//!
//! Paper shape (Eq. 12): the ratio scales LINEARLY with n_layers and is
//! roughly flat in the other components.

use mixflow::coordinator::report::axis_series;
use mixflow::coordinator::runner::{pair_ratios, ExperimentRunner, PairRatios, RunOptions};
use mixflow::coordinator::ResultsStore;
use mixflow::runtime::Runtime;
use mixflow::util::bench::Bench;

fn main() {
    let runtime = Runtime::new().expect("run make artifacts");
    let mut bench = Bench::new("fig6_components").with_iters(0, 1);
    let runner = ExperimentRunner::new(
        &runtime,
        RunOptions { timing_iters: 0, execute: false, seed: 0 },
    );

    let mut measurements = Vec::new();
    bench.run("component sweep (analysis)", || {
        measurements = runner.run_group("fig6_components");
    });
    let store = ResultsStore::discover().expect("results dir");
    for m in &measurements {
        store.append("fig6_components", m).ok();
    }
    let pairs = pair_ratios(&measurements);

    for axis in ["d_model", "ffw_size", "n_heads", "n_layers"] {
        let prefix = format!("comp_{axis}");
        let mut pts: Vec<(String, &PairRatios)> = pairs
            .iter()
            .filter(|p| p.size_name.starts_with(&prefix))
            .map(|p| {
                (
                    p.size_name.trim_start_matches(&prefix).to_string(),
                    p,
                )
            })
            .collect();
        pts.sort_by_key(|(v, _)| v.parse::<u64>().unwrap_or(0));
        if pts.is_empty() {
            continue;
        }
        println!(
            "{}",
            axis_series(
                &format!("Figure 6 — sweep over {axis}"),
                axis,
                &pts
            )
        );
    }

    // The headline check: gain(n_layers=16) / gain(n_layers=2) ≈ 8.
    let layer_ratio = |v: &str| {
        pairs
            .iter()
            .find(|p| p.size_name == format!("comp_n_layers{v}"))
            .map(|p| p.dynamic_ratio)
    };
    if let (Some(lo), Some(hi)) = (layer_ratio("2"), layer_ratio("16")) {
        println!(
            "layer-scaling check: ratio(L=16)/ratio(L=2) = {:.2} (Eq. 12 predicts ~8)",
            hi / lo
        );
    }
    bench.report();
}

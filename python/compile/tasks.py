"""The three bilevel-optimisation tasks of the paper's evaluation (§5.2).

Each task packages the pieces Eq. (3) needs:

* ``theta_init(eta, theta0)`` — how meta-parameters seed the inner model
  (identity for all but MAML, where ``θ₀ = η``);
* ``inner_loss(theta, eta, batch)`` — the train loss ``L(θ, η, x)``;
* ``apply_update(grads, theta, opt_state, eta)`` — the update ``Υ`` minus
  the gradient computation (paper Eq. 4's reparameterisation boundary);
* ``val_loss(theta, eta, val_batch)`` — the outer objective ``V``.

Tasks (Table 1):
  * ``learning_lr``   — per-parameter learning rates (Bengio 2000;
    Maclaurin et al. 2015): ``η`` is a pytree like ``θ`` of log-scale
    multipliers on the Adam update.
  * ``maml``          — learned initialisation (Finn et al. 2017).
  * ``loss_weighting``— per-datapoint loss weights ``α(η, x)`` (Hu et al.
    2023): ``η`` parameterises a weighting network over the batch.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from . import model as model_lib
from . import optim as optim_lib

PyTree = Any

TASK_NAMES = ("learning_lr", "maml", "loss_weighting")


@dataclasses.dataclass(frozen=True)
class BiLevelTask:
    """A bilevel problem instance (see module docstring)."""

    name: str
    cfg: model_lib.TransformerConfig
    theta_init: Callable[[PyTree, PyTree], PyTree]
    inner_loss: Callable[[PyTree, PyTree, jax.Array], jax.Array]
    apply_update: Callable[
        [PyTree, PyTree, Any, PyTree], Tuple[PyTree, Any]
    ]
    val_loss: Callable[[PyTree, PyTree, jax.Array], jax.Array]
    init_eta: Callable[[jax.Array], PyTree]
    init_theta: Callable[[jax.Array], PyTree]
    init_opt_state: Callable[[PyTree], Any]


# ---------------------------------------------------------------------------
# Task builders
# ---------------------------------------------------------------------------


def _ntp(cfg):
    return lambda theta, batch, weights=None: model_lib.ntp_loss(
        theta, batch, cfg, weights
    )


def make_learning_lr(
    cfg: model_lib.TransformerConfig,
    inner_optimizer: optim_lib.Optimizer | None = None,
) -> BiLevelTask:
    """Per-parameter learning rates: ``θ' = θ + exp(η) ⊙ adam_update``.

    ``η`` has the same structure as ``θ`` and is initialised to 0 (unit
    multiplier); the inner loss itself is η-independent, so the meta-signal
    flows purely through the update rule — the ``∂Υ/∂η`` term of Eq. (6).
    """
    opt = inner_optimizer or optim_lib.adam(1e-3)
    ntp = _ntp(cfg)

    def inner_loss(theta, eta, batch):
        del eta
        return ntp(theta, batch)

    def apply_update(grads, theta, opt_state, eta):
        upd, opt_state = opt.update(grads, opt_state, theta)
        theta = jax.tree.map(
            lambda t, u, e: t + jnp.exp(e) * u, theta, upd, eta
        )
        return theta, opt_state

    def val_loss(theta, eta, val_batch):
        del eta
        return ntp(theta, val_batch)

    def init_eta(rng):
        del rng
        params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
        return jax.tree.map(jnp.zeros_like, params)

    init_theta = lambda rng: model_lib.init_params(rng, cfg)

    return BiLevelTask(
        name="learning_lr",
        cfg=cfg,
        theta_init=lambda eta, theta0: theta0,
        inner_loss=inner_loss,
        apply_update=apply_update,
        val_loss=val_loss,
        init_eta=init_eta,
        init_theta=init_theta,
        init_opt_state=opt.init,
    )


def make_maml(
    cfg: model_lib.TransformerConfig,
    inner_optimizer: optim_lib.Optimizer | None = None,
) -> BiLevelTask:
    """MAML (Finn et al. 2017): ``η`` is the inner initialisation ``θ₀``."""
    opt = inner_optimizer or optim_lib.adam(1e-3)
    ntp = _ntp(cfg)

    def inner_loss(theta, eta, batch):
        del eta
        return ntp(theta, batch)

    def apply_update(grads, theta, opt_state, eta):
        del eta
        upd, opt_state = opt.update(grads, opt_state, theta)
        return jax.tree.map(lambda t, u: t + u, theta, upd), opt_state

    def val_loss(theta, eta, val_batch):
        del eta
        return ntp(theta, val_batch)

    init_eta = lambda rng: model_lib.init_params(rng, cfg)

    def init_theta(rng):
        # θ₀ is replaced by η at meta-step entry; keep a placeholder with
        # the right structure so all tasks share one calling convention.
        return model_lib.init_params(rng, cfg)

    return BiLevelTask(
        name="maml",
        cfg=cfg,
        theta_init=lambda eta, theta0: eta,
        inner_loss=inner_loss,
        apply_update=apply_update,
        val_loss=val_loss,
        init_eta=init_eta,
        init_theta=init_theta,
        init_opt_state=opt.init,
    )


def make_loss_weighting(
    cfg: model_lib.TransformerConfig,
    inner_optimizer: optim_lib.Optimizer | None = None,
    weight_hidden: int = 32,
) -> BiLevelTask:
    """Meta-learned per-datapoint loss weights ``α(η, x)`` (Hu et al. 2023).

    ``η`` parameterises a small weighting network: embed the example's
    tokens with a learned table, mean-pool, 2-layer MLP → softplus weight,
    normalised to mean 1 across the batch.  ``L = α(η, x) · NTP(θ, x)``
    makes the mixed term ``∂²L/∂η∂θ`` of Eq. (8) dense and non-trivial.
    """
    opt = inner_optimizer or optim_lib.adam(1e-3)
    ntp = _ntp(cfg)

    def alpha(eta, batch):
        # batch: [B, S+1] int tokens.
        h = jnp.take(eta["embed"], batch[:, :-1], axis=0)  # [B, S, e]
        h = jnp.mean(h, axis=1)  # [B, e]
        h = jnp.tanh(h @ eta["w1"] + eta["b1"])
        w = jax.nn.softplus(h @ eta["w2"] + eta["b2"])[:, 0]  # [B]
        return w / (jnp.mean(w) + 1e-8)

    def inner_loss(theta, eta, batch):
        return ntp(theta, batch, weights=alpha(eta, batch))

    def apply_update(grads, theta, opt_state, eta):
        del eta
        upd, opt_state = opt.update(grads, opt_state, theta)
        return jax.tree.map(lambda t, u: t + u, theta, upd), opt_state

    def val_loss(theta, eta, val_batch):
        del eta
        return ntp(theta, val_batch)  # unweighted validation NTP

    def init_eta(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        e = weight_hidden
        return {
            "embed": jax.random.normal(k1, (cfg.vocab_size, e)) * 0.02,
            "w1": jax.random.normal(k2, (e, e)) / math.sqrt(e),
            "b1": jnp.zeros((e,)),
            "w2": jax.random.normal(k3, (e, 1)) / math.sqrt(e),
            "b2": jnp.zeros((1,)),
        }

    init_theta = lambda rng: model_lib.init_params(rng, cfg)

    return BiLevelTask(
        name="loss_weighting",
        cfg=cfg,
        theta_init=lambda eta, theta0: theta0,
        inner_loss=inner_loss,
        apply_update=apply_update,
        val_loss=val_loss,
        init_eta=init_eta,
        init_theta=init_theta,
        init_opt_state=opt.init,
    )


BUILDERS = {
    "learning_lr": make_learning_lr,
    "maml": make_maml,
    "loss_weighting": make_loss_weighting,
}


def by_name(
    name: str,
    cfg: model_lib.TransformerConfig,
    inner_optimizer: optim_lib.Optimizer | None = None,
) -> BiLevelTask:
    """Build a Table-1 task by name."""
    return BUILDERS[name](cfg, inner_optimizer)

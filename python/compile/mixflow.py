"""MixFlow-MG: mixed-mode differentiation for bilevel gradients (paper §3).

This module is the paper's contribution:

* :func:`get_grad_fn` — the ``fwdrev_grad`` transformation of Algorithm 2 /
  Listing 1 (plus the reverse-over-forward and explicit reverse-over-reverse
  alternatives Proposition 3.1 mentions).  Each returns a drop-in replacement
  for ``jax.grad(inner_loss_fn)`` whose *backward* rule computes the
  Hessian-vector and mixed-derivative products of Eqs. (7)–(8) in the chosen
  mode instead of default reverse-over-reverse.

* :func:`tag_inner_grads` / :func:`checkpoint_inner_step` — the
  "saving inner gradients" optimisation of §4 (Listing 3): tag ``∇L_i`` with
  ``checkpoint_name`` and checkpoint each inner step with a
  ``save_only_these_names`` policy so the outer backward pass never redoes
  the inner backward pass.

* :func:`build_meta_loss` / :func:`build_meta_grad` — assemble a complete
  Truncated-BPTT meta-gradient program (Algorithm 1 when
  ``mode='default'``, Algorithm 2 otherwise) for any
  :class:`compile.tasks.BiLevelTask`.

Everything here is exact — MixFlow-MG changes *how* the second-order
products are evaluated, never their value; ``python/tests/test_mixflow.py``
asserts bit-level-tolerance agreement between all modes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

PyTree = Any

#: The differentiation modes of Proposition 3.1 for the second-order
#: products inside the outer backward pass.
MODES = ("default", "fwdrev", "revfwd", "revrev")


# ---------------------------------------------------------------------------
# The core transformation (paper Listing 1 + Proposition 3.1)
# ---------------------------------------------------------------------------


def _is_differentiable(tree: PyTree) -> bool:
    """True iff every leaf has an inexact dtype (token batches are int)."""
    leaves = jax.tree.leaves(tree)
    return bool(leaves) and all(
        jnp.issubdtype(jnp.asarray(l).dtype, jnp.inexact) for l in leaves
    )


def _diff_input_positions(inputs: Sequence[PyTree]) -> tuple:
    """Positions (within ``inputs``) that can carry cotangents."""
    return tuple(
        i for i, a in enumerate(inputs) if _is_differentiable(a)
    )


def _scatter_cotangents(inputs, positions, cts):
    """Place ``cts`` at ``positions``; ``None`` elsewhere (int inputs)."""
    out = [None] * len(inputs)
    for p, ct in zip(positions, cts):
        out[p] = ct
    return tuple(out)


def get_fwdrev_grad_fn(inner_loss_fn: Callable[..., jax.Array]):
    """Forward-over-reverse ``grad(inner_loss_fn)`` (paper Listing 1).

    The returned function computes ``∂L/∂θ`` exactly like
    ``jax.grad(inner_loss_fn)``, but defines a custom VJP that evaluates the
    cotangent products

      ``ct ↦ (∂²L/∂θ² · ct,  ∂²L/∂inputs∂θ · ct)``

    as a **JVP of the gradient** (``jax.jvp(grad(L), (θ,), (ct,))``): the
    HVP of Eq. (7) and the MVP of Eq. (8), both in forward-over-reverse
    mode.  Symmetry of the Hessian / Schwarz's theorem (§3) makes this equal
    to the default reverse-over-reverse products while storing no
    activations of the inner backward pass.

    Args:
      inner_loss_fn: scalar loss ``L(params, *inputs)``; ``params`` must be
        the first argument.  Integer-dtype inputs (token batches) are
        detected automatically and receive ``None`` cotangents.

    Returns:
      A function with signature ``(params, *inputs) -> ∂L/∂params``.
    """

    @jax.custom_vjp
    def fwdrev_grad_fn(params, *inputs):
        return jax.grad(inner_loss_fn)(params, *inputs)

    def forward_pass(params, *inputs):
        # Residuals are the *primal* point only — no inner-backward
        # activations are saved, which is the entire memory story.
        return fwdrev_grad_fn(params, *inputs), (params, inputs)

    def backward_pass(residuals, ct):
        params, inputs = residuals
        diff_pos = _diff_input_positions(inputs)
        grad_loss_fn = jax.grad(
            inner_loss_fn, argnums=(0,) + tuple(p + 1 for p in diff_pos)
        )
        _, hvp_ct = jax.jvp(
            lambda p: grad_loss_fn(p, *inputs), (params,), (ct,)
        )
        return (hvp_ct[0],) + _scatter_cotangents(
            inputs, diff_pos, hvp_ct[1:]
        )

    fwdrev_grad_fn.defvjp(forward_pass, backward_pass)
    return fwdrev_grad_fn


def get_revfwd_grad_fn(inner_loss_fn: Callable[..., jax.Array]):
    """Reverse-over-forward ``grad(inner_loss_fn)`` (Proposition 3.1).

    The cotangent products are evaluated as the gradient of the directional
    derivative ``⟨∂L/∂θ, ct⟩``: reverse mode over a forward-mode product
    (``VJP(e, JVP(L, v))`` in §2.2's taxonomy).  By Schwarz's theorem this
    equals the same HVP/MVP as :func:`get_fwdrev_grad_fn`.
    """

    @jax.custom_vjp
    def revfwd_grad_fn(params, *inputs):
        return jax.grad(inner_loss_fn)(params, *inputs)

    def forward_pass(params, *inputs):
        return revfwd_grad_fn(params, *inputs), (params, inputs)

    def backward_pass(residuals, ct):
        params, inputs = residuals
        diff_pos = _diff_input_positions(inputs)

        def directional(p, *ins):
            # d/dε L(p + ε·ct, *ins) — a scalar, cheap in forward mode.
            return jax.jvp(
                lambda pp: inner_loss_fn(pp, *ins), (p,), (ct,)
            )[1]

        cts = jax.grad(
            directional, argnums=(0,) + tuple(p + 1 for p in diff_pos)
        )(params, *inputs)
        return (cts[0],) + _scatter_cotangents(inputs, diff_pos, cts[1:])

    revfwd_grad_fn.defvjp(forward_pass, backward_pass)
    return revfwd_grad_fn


def get_revrev_grad_fn(inner_loss_fn: Callable[..., jax.Array]):
    """Explicit reverse-over-reverse ``grad(inner_loss_fn)``.

    Numerically identical to what default autodiff produces for Algorithm 1;
    exists so benchmarks can isolate the *reparameterisation* (Eq. 4) from
    the *mode switch* (Eqs. 7–8) — with this, the program structure matches
    Algorithm 2 while the second-order products stay reverse-over-reverse.
    """

    @jax.custom_vjp
    def revrev_grad_fn(params, *inputs):
        return jax.grad(inner_loss_fn)(params, *inputs)

    def forward_pass(params, *inputs):
        return revrev_grad_fn(params, *inputs), (params, inputs)

    def backward_pass(residuals, ct):
        params, inputs = residuals
        diff_pos = _diff_input_positions(inputs)
        diff_inputs = [inputs[p] for p in diff_pos]

        def grad_of_diff(p, *dins):
            ins = list(inputs)
            for pos, a in zip(diff_pos, dins):
                ins[pos] = a
            return jax.grad(inner_loss_fn)(p, *ins)

        _, vjp_fn = jax.vjp(grad_of_diff, params, *diff_inputs)
        cts = vjp_fn(ct)
        return (cts[0],) + _scatter_cotangents(inputs, diff_pos, cts[1:])

    revrev_grad_fn.defvjp(forward_pass, backward_pass)
    return revrev_grad_fn


def get_grad_fn(inner_loss_fn: Callable[..., jax.Array], mode: str):
    """Gradient transform for ``mode`` ∈ :data:`MODES`.

    ``'default'`` is plain ``jax.grad`` — Algorithm 1's un-reparameterised
    baseline.  The other three are the reparameterised (Eq. 4) variants with
    the second-order products in the named mode.
    """
    if mode == "default":
        return jax.grad(inner_loss_fn)
    if mode == "fwdrev":
        return get_fwdrev_grad_fn(inner_loss_fn)
    if mode == "revfwd":
        return get_revfwd_grad_fn(inner_loss_fn)
    if mode == "revrev":
        return get_revrev_grad_fn(inner_loss_fn)
    raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")


# ---------------------------------------------------------------------------
# Saving inner gradients (paper §4 optimisation 2, Listing 3)
# ---------------------------------------------------------------------------

INNER_GRADS_NAME = "inner_grads"


def tag_inner_grads(d_params: PyTree) -> PyTree:
    """Mark ``∇L_i`` as checkpointable (Listing 3's ``checkpoint_name``)."""
    from jax import ad_checkpoint

    return jax.tree.map(
        lambda x: ad_checkpoint.checkpoint_name(x, INNER_GRADS_NAME),
        d_params,
    )


def checkpoint_inner_step(step_fn, save_inner_grads: bool):
    """Per-inner-step gradient checkpointing (paper §4).

    With ``save_inner_grads`` the rematerialisation policy additionally
    saves the tagged ``∇L_i``, so the outer backward pass re-runs only the
    (cheap) optimiser arithmetic, never the inner backward pass.
    """
    if save_inner_grads:
        policy = jax.checkpoint_policies.save_only_these_names(
            INNER_GRADS_NAME
        )
        return jax.checkpoint(step_fn, policy=policy)
    return jax.checkpoint(step_fn)


# ---------------------------------------------------------------------------
# Full Truncated-BPTT meta-gradient programs (Algorithms 1 & 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MetaFlags:
    """The ablation grid of §4 / Tables 2–3."""

    mode: str = "fwdrev"          # 'default' == Algorithm 1
    save_inner_grads: bool = True  # §4 optimisation 2
    per_step_checkpoint: bool = True  # inner-loop gradient checkpointing
    inner_steps: int = 2           # T

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.save_inner_grads and not self.per_step_checkpoint:
            raise ValueError(
                "save_inner_grads requires per_step_checkpoint "
                "(the policy lives on the per-step checkpoint)"
            )


def build_meta_loss(task, flags: MetaFlags):
    """The ``VALLOSS`` function of Algorithms 1/2 for ``task``.

    Args:
      task: a :class:`compile.tasks.BiLevelTask`.
      flags: ablation switches (mode / checkpointing).

    Returns:
      ``meta_loss(eta, theta0, opt_state, xs, val_batch) -> scalar`` where
      ``xs`` is a length-``T`` stack of inner batches (leading axis scanned).
    """

    # The transform is created once, outside any trace: token batches are
    # explicit arguments (with ``None`` cotangents), never closure captures.
    grad_fn = get_grad_fn(task.inner_loss, flags.mode)

    def meta_loss(eta, theta0, opt_state, xs, val_batch):
        theta = task.theta_init(eta, theta0)

        def inner_step(carry, batch):
            theta, opt_state = carry
            # 'default' == Algorithm 1 (Φ computes grad(L) inline, plain
            # jax.grad); otherwise Algorithm 2's Υ takes ∇L from the
            # reparameterised mixed-mode transform.
            d_theta = grad_fn(theta, eta, batch)
            if flags.save_inner_grads:
                d_theta = tag_inner_grads(d_theta)
            theta, opt_state = task.apply_update(
                d_theta, theta, opt_state, eta
            )
            return (theta, opt_state), ()

        step = inner_step
        if flags.per_step_checkpoint:
            step = checkpoint_inner_step(step, flags.save_inner_grads)

        (theta_t, _), _ = jax.lax.scan(step, (theta, opt_state), xs)
        return task.val_loss(theta_t, eta, val_batch)

    return meta_loss


def build_meta_grad(task, flags: MetaFlags, with_aux: bool = True):
    """``∂V/∂η`` for ``task`` under ``flags``.

    Returns ``f(eta, theta0, opt_state, xs, val_batch) -> (dV/dη, V)`` when
    ``with_aux`` (the validation loss rides along for logging), else just
    the gradient.
    """
    meta_loss = build_meta_loss(task, flags)
    if with_aux:

        def loss_and_val(eta, *args):
            v = meta_loss(eta, *args)
            return v, v

        return jax.grad(loss_and_val, has_aux=True)
    return jax.grad(meta_loss)


def build_meta_train_step(
    task,
    flags: MetaFlags,
    meta_optimizer,
):
    """One full outer update: meta-gradient + meta-optimiser application.

    This is the function the Rust E2E driver executes in a loop: it is
    lowered once to a single HLO artifact so the entire outer step — inner
    unroll, mixed-mode backward, Adam on ``η`` — runs on-device with Python
    nowhere near the hot path.

    Returns:
      ``step(eta, meta_opt_state, theta0, opt_state, xs, val_batch)
        -> (eta', meta_opt_state', val_loss)``.
    """
    meta_grad = build_meta_grad(task, flags, with_aux=True)

    def train_step(eta, meta_opt_state, theta0, opt_state, xs, val_batch):
        g, val = meta_grad(eta, theta0, opt_state, xs, val_batch)
        upd, meta_opt_state = meta_optimizer.update(g, meta_opt_state, eta)
        eta = jax.tree.map(lambda e, u: e + u, eta, upd)
        return eta, meta_opt_state, val

    return train_step

"""Pallas kernel for the paper's motivating example (Eq. 9 recursive map).

``y_i = i * (2 + sin(y_{i-1})) ** cos(y_{i-1})``, iterated ``M`` times over a
``[B, D]`` activation.  The map is elementwise, so the whole chain fuses into
one VPU-resident tile: the default autodiff implementation instead stores all
``M`` intermediates for the backward pass, which is precisely the asymmetry
Figure 1 of the paper plots.  ``interpret=True`` per DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8


def _largest_divisor(n: int, cap: int) -> int:
    best = 1
    for d in range(1, min(n, cap) + 1):
        if n % d == 0:
            best = d
    return best


def _toy_map_kernel(y_ref, o_ref, *, num_maps: int):
    y = y_ref[...].astype(jnp.float32)
    for i in range(1, num_maps + 1):
        y = i * (2.0 + jnp.sin(y)) ** jnp.cos(y)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_maps", "block_rows"))
def toy_map(
    y0: jax.Array, num_maps: int, block_rows: int | None = None
) -> jax.Array:
    """Apply the Eq. (9) map ``num_maps`` times (matches ``ref.toy_map``)."""
    rows, d = y0.shape
    br = block_rows or _largest_divisor(rows, DEFAULT_BLOCK_ROWS)
    assert rows % br == 0, (rows, br)
    return pl.pallas_call(
        functools.partial(_toy_map_kernel, num_maps=num_maps),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), y0.dtype),
        interpret=True,
    )(y0)


def vmem_bytes_estimate(d: int, block_rows: int = DEFAULT_BLOCK_ROWS,
                        dtype_bytes: int = 4) -> int:
    """One tile in, one tile out, f32 working copy — M-independent."""
    return block_rows * d * (4 + dtype_bytes + 4)

"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth that ``python/tests`` (incl. hypothesis sweeps)
compare the kernels against, and the differentiable "tangent" bodies used by
the ``custom_jvp`` wrappers in :mod:`compile.kernels.wrappers` — they must be
written in plain ``jnp`` so JAX can differentiate them to any order.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def causal_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Reference multi-head causal attention.

    Args:
      q, k, v: ``[B, H, S, D]`` arrays.

    Returns:
      ``[B, H, S, D]`` attention output, computed with a dense causal mask
      and numerically-stable softmax in f32.
    """
    s = q.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(
        q.dtype
    )


def layernorm(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5
) -> jax.Array:
    """Reference LayerNorm over the last axis (stats in f32)."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * gamma + beta).astype(x.dtype)


def toy_map(y0: jax.Array, num_maps: int) -> jax.Array:
    """Reference for the paper's Eq. (9) recursive map.

    ``y_i = i * (2 + sin(y_{i-1})) ** cos(y_{i-1})`` for ``i = 1..num_maps``.
    """
    y = y0
    for i in range(1, num_maps + 1):
        y = i * (2.0 + jnp.sin(y)) ** jnp.cos(y)
    return y

"""Higher-order-differentiable wrappers around the Pallas kernels.

``pallas_call`` has no autodiff rule, but MixFlow-MG differentiates the inner
loss **twice** (the HVP/MVP products of Eqs. 7–8), in both forward and
reverse mode.  Each kernel is therefore wrapped in ``jax.custom_jvp`` whose
rule

1. computes the **primal** by recursively calling the wrapped kernel — so the
   Pallas kernel stays on the primal path at every differentiation order, and
2. computes the **tangent** with the pure-``jnp`` reference from ``ref.py`` —
   differentiable to any order, so ``grad``, ``jvp∘grad`` (forward-over-
   reverse) and ``grad∘grad`` (reverse-over-reverse) all compose.

Reverse mode falls out of JAX's linearize-then-transpose of the rule.  The
redundant reference primal inside ``jax.jvp`` is dead code XLA eliminates
(only ops shared with the tangent survive).
"""

from __future__ import annotations

import functools

import jax

from . import attention as _attention
from . import layernorm as _layernorm
from . import ref as _ref
from . import toy_map as _toy_map


def make_differentiable(kernel_fn, ref_fn):
    """Wrap ``kernel_fn`` so it is differentiable to any order.

    Args:
      kernel_fn: the Pallas kernel entry point (array args only).
      ref_fn: pure-jnp function with identical semantics/signature.

    Returns:
      A function numerically equal to ``kernel_fn`` whose JVP (and hence
      VJP, and higher-order derivatives) are defined via ``ref_fn``.
    """
    wrapped = jax.custom_jvp(kernel_fn)

    @wrapped.defjvp
    def _jvp(primals, tangents):  # noqa: ANN001 — jax callback signature
        primal_out = wrapped(*primals)
        _, tangent_out = jax.jvp(ref_fn, primals, tangents)
        return primal_out, tangent_out

    return wrapped


#: Differentiable fused causal attention: ``[B, H, S, D]`` q/k/v → output.
causal_attention = make_differentiable(
    lambda q, k, v: _attention.causal_attention(q, k, v),
    _ref.causal_attention,
)

#: Differentiable fused LayerNorm over the last axis.
layernorm = make_differentiable(
    lambda x, g, b: _layernorm.layernorm(x, g, b),
    _ref.layernorm,
)


@functools.lru_cache(maxsize=None)
def toy_map(num_maps: int):
    """Differentiable Eq. (9) map with ``num_maps`` baked in (cached)."""
    return make_differentiable(
        lambda y0: _toy_map.toy_map(y0, num_maps),
        lambda y0: _ref.toy_map(y0, num_maps),
    )

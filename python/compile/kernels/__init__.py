"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

Public surface:
  * ``wrappers.causal_attention`` / ``wrappers.layernorm`` /
    ``wrappers.toy_map`` — differentiable kernel entry points used by the
    L2 model code.
  * ``ref`` — pure-jnp oracles (pytest ground truth).
"""

from . import attention, layernorm, ref, toy_map, wrappers  # noqa: F401

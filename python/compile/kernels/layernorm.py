"""Fused LayerNorm Pallas kernel (row-tiled, f32 statistics).

The Chinchilla blocks are pre-LN; with block rematerialisation on (the
paper's §4 optimisation 1), each LayerNorm runs in both the forward pass and
every recomputation, so fusing the two reduction passes and the affine into
a single VMEM-resident tile pays off on TPU.  ``interpret=True`` per
DESIGN.md (CPU PJRT cannot execute Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step; 8 sublanes x f32 is the native TPU tile height.
DEFAULT_BLOCK_ROWS = 8


def _largest_divisor(n: int, cap: int) -> int:
    best = 1
    for d in range(1, min(n, cap) + 1):
        if n % d == 0:
            best = d
    return best


def _layernorm_kernel(x_ref, gamma_ref, beta_ref, o_ref, *, eps: float):
    """Normalise a ``(block_rows, D)`` tile over its last axis."""
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    centred = x - mean
    var = jnp.mean(centred * centred, axis=-1, keepdims=True)
    y = centred * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * gamma_ref[...] + beta_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def layernorm(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    eps: float = 1e-5,
    block_rows: int | None = None,
) -> jax.Array:
    """Pallas LayerNorm over the last axis of ``x`` (any leading shape).

    Matches :func:`compile.kernels.ref.layernorm`.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    br = block_rows or _largest_divisor(rows, DEFAULT_BLOCK_ROWS)
    assert rows % br == 0, (rows, br)

    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(xf, gamma, beta)
    return out.reshape(orig_shape)


def vmem_bytes_estimate(d_model: int, block_rows: int = DEFAULT_BLOCK_ROWS,
                        dtype_bytes: int = 4) -> int:
    """VMEM estimate for one grid step: x tile (f32) + params + out tile."""
    f32 = 4
    return block_rows * d_model * f32 + 2 * d_model * f32 + (
        block_rows * d_model * dtype_bytes
    )

"""Fused causal flash-attention Pallas kernel (TPU-style, interpret mode).

The paper's inner models are Chinchilla transformers; self-attention is the
compute hot-spot, and its activation footprint (``O(B·L·k·S²)``) is exactly
the term MixFlow-MG's analysis (§5.3, Eq. 12) targets.  This kernel follows
the TPU adaptation rules from DESIGN.md §Hardware-Adaptation:

* tiles are shaped for **VMEM** via ``BlockSpec`` — one query block plus the
  streamed K/V blocks live on-chip at a time (no ``S×S`` logits in HBM);
* the contraction feeds the **MXU** (block matmuls in f32 accumulation);
* the HBM↔VMEM schedule the CUDA implementations express with threadblocks
  is expressed with the grid + ``BlockSpec`` index maps.

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.  Interpret mode
lowers to plain HLO, so the kernel participates in the same AOT artifact the
Rust runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: multiples of the TPU (8, 128) register tiling; the MXU
# is a 128x128 systolic array, so 128-wide query/key tiles keep it fed.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128

_NEG_INF = -1e30


def _largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is ``<= cap`` (>=1)."""
    best = 1
    for d in range(1, min(n, cap) + 1):
        if n % d == 0:
            best = d
    return best


def _attention_kernel(
    q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_kv: int, seq_len: int
):
    """One (batch*head, q-block) grid step of causal flash attention.

    Ref shapes: q ``(1, block_q, d)``; k, v ``(1, seq_len, d)`` (streamed in
    ``block_kv`` slices); o ``(1, block_q, d)``.  Online-softmax state
    (running max ``m``, normaliser ``l``, accumulator ``acc``) is carried in
    f32 — the MXU accumulates in f32 even for bf16 operands, and so do we.
    """
    q_block = pl.program_id(1)
    d = q_ref.shape[-1]
    scale = 1.0 / (d ** 0.5)

    q = q_ref[0].astype(jnp.float32) * scale  # [bq, d]

    acc = jnp.zeros((block_q, d), jnp.float32)
    m = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)

    num_kv_blocks = seq_len // block_kv
    for j in range(num_kv_blocks):
        k = k_ref[0, j * block_kv : (j + 1) * block_kv, :].astype(jnp.float32)
        v = v_ref[0, j * block_kv : (j + 1) * block_kv, :].astype(jnp.float32)
        s = q @ k.T  # [bq, bkv] — MXU tile
        q_pos = q_block * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0
        )
        k_pos = j * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1
        )
        mask = q_pos >= k_pos
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        m = m_new

    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv"))
def causal_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int | None = None,
    block_kv: int | None = None,
) -> jax.Array:
    """Pallas fused causal attention over ``[B, H, S, D]`` inputs.

    Numerics match :func:`compile.kernels.ref.causal_attention` (the pytest
    oracle).  Block sizes default to the largest divisors of ``S`` below the
    MXU-friendly 128.
    """
    b, h, s, d = q.shape
    bq = block_q or _largest_divisor(s, DEFAULT_BLOCK_Q)
    bkv = block_kv or _largest_divisor(s, DEFAULT_BLOCK_KV)
    assert s % bq == 0 and s % bkv == 0, (s, bq, bkv)

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)

    grid = (b * h, s // bq)
    kernel = functools.partial(
        _attention_kernel, block_q=bq, block_kv=bkv, seq_len=s
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, s, d), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((1, s, d), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


def vmem_bytes_estimate(
    seq_len: int, head_dim: int, block_q: int | None = None,
    block_kv: int | None = None, dtype_bytes: int = 4,
) -> int:
    """VMEM footprint estimate for one grid step (DESIGN.md §7).

    q tile + one k/v tile pair + logits tile + online-softmax state + output
    accumulator, all in f32 (4 B) except the HBM-resident operands.
    """
    bq = block_q or _largest_divisor(seq_len, DEFAULT_BLOCK_Q)
    bkv = block_kv or _largest_divisor(seq_len, DEFAULT_BLOCK_KV)
    f32 = 4
    tiles = (
        bq * head_dim * f32          # q (scaled, f32)
        + 2 * bkv * head_dim * f32   # k, v tiles
        + bq * bkv * f32             # logits/probs tile
        + bq * head_dim * f32        # accumulator
        + 2 * bq * f32               # m, l
        + bq * head_dim * dtype_bytes  # output tile in storage dtype
    )
    return tiles


def mxu_flops_per_step(seq_len: int, head_dim: int, block_q: int | None = None,
                       block_kv: int | None = None) -> int:
    """MXU FLOPs per grid step: the two block matmuls over all kv tiles."""
    bq = block_q or _largest_divisor(seq_len, DEFAULT_BLOCK_Q)
    bkv = block_kv or _largest_divisor(seq_len, DEFAULT_BLOCK_KV)
    num_kv = seq_len // bkv
    per_tile = 2 * bq * bkv * head_dim  # q@k.T
    per_tile += 2 * bq * bkv * head_dim  # p@v
    return per_tile * num_kv

"""AOT pipeline: lower every benchmark configuration to HLO text artifacts.

This is the single build-time entry point (``make artifacts``).  For each
experiment configuration of DESIGN.md §4 it

1. builds the meta-gradient (or full train-step / toy) function,
2. flattens its pytree signature to a positional array list,
3. lowers with ``jax.jit(...).lower(...)`` and converts the StableHLO to
   **HLO text** (the interchange the ``xla`` crate's 0.5.1 extension can
   parse — serialized protos from jax≥0.5 are rejected, see
   /opt/xla-example/README.md),
4. optionally compiles on the CPU backend to record XLA's
   ``CompiledMemoryStats`` (the "measured peak HBM" stand-in, DESIGN.md §2),
5. records everything in ``artifacts/manifest.json`` for the Rust runtime.

Artifacts are content-keyed and deduplicated across figure groups; an
existing file is skipped unless ``--force``.

Usage::

    cd python && python -m compile.aot --out ../artifacts [--full] [--force]
                                       [--groups fig4,table3]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import mixflow
from . import model as model_lib
from . import optim as optim_lib
from . import tasks as tasks_lib
from . import toy as toy_lib

# ---------------------------------------------------------------------------
# Scaled model presets (DESIGN.md §2: CPU-budget proportional scaling)
# ---------------------------------------------------------------------------

SIZES: Dict[str, Dict[str, int]] = {
    "tiny": dict(d_model=32, ffw_size=128, kv_size=8, n_heads=4, n_layers=2),
    "small": dict(d_model=48, ffw_size=192, kv_size=8, n_heads=6, n_layers=4),
}
# The scaled Chinchilla ladder rungs join the size table under their names.
for _name, (_d, _f, _kv, _h, _l) in model_lib.CHINCHILLA_LADDER.items():
    SIZES[_name] = dict(
        d_model=_d, ffw_size=_f, kv_size=_kv, n_heads=_h, n_layers=_l
    )

VOCAB = 128

DEFAULT_VARIANTS = {
    # Algorithm 1: plain autodiff, block remat on (paper keeps it on
    # everywhere), no inner-grad saving.
    "default": dict(mode="default", block_remat=True, save_inner_grads=False),
    # Algorithm 2: MixFlow-MG = fwdrev + block remat + save inner grads.
    "mixflow": dict(mode="fwdrev", block_remat=True, save_inner_grads=True),
}


def _dtype_name(dt) -> str:
    return np.dtype(dt).name  # 'float32', 'int32', ...


@dataclasses.dataclass
class Artifact:
    """One lowered HLO artifact plus the metadata Rust needs to run it."""

    key: str
    kind: str                  # 'meta_grad' | 'train_step' | 'toy'
    task: str
    variant: str               # 'default' | 'mixflow' | ablation tag
    mode: str
    block_remat: bool
    save_inner_grads: bool
    tier: str                  # 'exec' | 'analysis'
    model: Dict[str, Any]
    inner_steps: int
    batch: int
    seq_len: int
    vocab_size: int
    inputs: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    outputs: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    file: str = ""
    xla_stats: Dict[str, int] | None = None
    cost: Dict[str, float] | None = None
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)
    lower_seconds: float = 0.0


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flatten_fn(fn: Callable, example_args) -> tuple:
    """Positional-array wrapper + flat input specs for ``fn``.

    Returns ``(flat_fn, leaf_specs)`` where ``flat_fn(*arrays)`` returns a
    flat tuple of output arrays and ``leaf_specs`` is the list of
    ``ShapeDtypeStruct`` for the flattened ``example_args``.
    """
    spec_args = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), example_args
    )
    leaves, treedef = jax.tree.flatten(spec_args)

    def flat_fn(*flat):
        args = jax.tree.unflatten(treedef, list(flat))
        return tuple(jax.tree.leaves(fn(*args)))

    return flat_fn, leaves


# ---------------------------------------------------------------------------
# Builders for each artifact kind
# ---------------------------------------------------------------------------


def _model_cfg(size: str, seq_len: int, block_remat: bool,
               use_pallas: bool = False) -> model_lib.TransformerConfig:
    return model_lib.TransformerConfig(
        vocab_size=VOCAB,
        seq_len=seq_len,
        block_remat=block_remat,
        use_pallas=use_pallas,
        **SIZES[size],
    )


def build_meta_grad_artifact(
    task_name: str,
    size: str,
    seq_len: int,
    batch: int,
    inner_steps: int,
    variant: str,
    *,
    mode: str,
    block_remat: bool,
    save_inner_grads: bool,
    tier: str,
    use_pallas: bool = False,
) -> tuple:
    """(Artifact, flat_fn, leaf_specs) for one ∂V/∂η configuration."""
    cfg = _model_cfg(size, seq_len, block_remat, use_pallas)
    task = tasks_lib.by_name(task_name, cfg)
    flags = mixflow.MetaFlags(
        mode=mode,
        save_inner_grads=save_inner_grads,
        per_step_checkpoint=True,
        inner_steps=inner_steps,
    )
    fn = mixflow.build_meta_grad(task, flags, with_aux=False)

    rng = jax.random.PRNGKey(0)
    eta = task.init_eta(rng)
    theta0 = task.init_theta(jax.random.PRNGKey(1))
    opt0 = task.init_opt_state(theta0)
    xs = jnp.zeros((inner_steps, batch, seq_len + 1), jnp.int32)
    val = jnp.zeros((batch, seq_len + 1), jnp.int32)
    flat, leaves = flatten_fn(fn, (eta, theta0, opt0, xs, val))

    key = (
        f"{task_name}_{size}_S{seq_len}_B{batch}_T{inner_steps}"
        f"_{mode}_br{int(block_remat)}_sg{int(save_inner_grads)}"
        + ("_pallas" if use_pallas else "")
    )
    art = Artifact(
        key=key,
        kind="meta_grad",
        task=task_name,
        variant=variant,
        mode=mode,
        block_remat=block_remat,
        save_inner_grads=save_inner_grads,
        tier=tier,
        model={**SIZES[size], "size_name": size,
               "param_count": cfg.param_count()},
        inner_steps=inner_steps,
        batch=batch,
        seq_len=seq_len,
        vocab_size=VOCAB,
        extra={"use_pallas": use_pallas},
    )
    return art, flat, leaves


def build_train_step_artifact(
    task_name: str,
    size: str,
    seq_len: int,
    batch: int,
    inner_steps: int,
    variant: str,
    *,
    mode: str,
    block_remat: bool,
    save_inner_grads: bool,
    meta_lr: float = 1e-2,
    use_pallas: bool = False,
    out_dir: str,
) -> tuple:
    """Full outer step (meta-grad + meta-Adam) + init-state npz for Rust."""
    cfg = _model_cfg(size, seq_len, block_remat, use_pallas)
    task = tasks_lib.by_name(task_name, cfg)
    flags = mixflow.MetaFlags(
        mode=mode,
        save_inner_grads=save_inner_grads,
        per_step_checkpoint=True,
        inner_steps=inner_steps,
    )
    meta_opt = optim_lib.adam(meta_lr)
    fn = mixflow.build_meta_train_step(task, flags, meta_opt)

    rng = jax.random.PRNGKey(0)
    eta = task.init_eta(rng)
    meta_state = meta_opt.init(eta)
    theta0 = task.init_theta(jax.random.PRNGKey(1))
    opt0 = task.init_opt_state(theta0)
    xs = jnp.zeros((inner_steps, batch, seq_len + 1), jnp.int32)
    val = jnp.zeros((batch, seq_len + 1), jnp.int32)
    args = (eta, meta_state, theta0, opt0, xs, val)
    flat, leaves = flatten_fn(fn, args)

    key = f"train_{task_name}_{size}_S{seq_len}_B{batch}_T{inner_steps}_{mode}" + (
        "_pallas" if use_pallas else ""
    )

    # Dump the initial state so Rust starts from a proper initialisation
    # (LayerNorm gains at 1, scaled normals, zero Adam moments).
    state_leaves = jax.tree.leaves((eta, meta_state, theta0, opt0))
    init_path = os.path.join(out_dir, f"{key}.init.npz")
    np.savez(
        init_path,
        **{
            f"in_{i:04d}": np.asarray(x)
            for i, x in enumerate(state_leaves)
        },
    )

    n_eta = len(jax.tree.leaves(eta))
    n_meta = len(jax.tree.leaves(meta_state))
    art = Artifact(
        key=key,
        kind="train_step",
        task=task_name,
        variant=variant,
        mode=mode,
        block_remat=block_remat,
        save_inner_grads=save_inner_grads,
        tier="exec",
        model={**SIZES[size], "size_name": size,
               "param_count": cfg.param_count()},
        inner_steps=inner_steps,
        batch=batch,
        seq_len=seq_len,
        vocab_size=VOCAB,
        extra={
            "use_pallas": use_pallas,
            "init_file": os.path.basename(init_path),
            # Outputs [0, n_eta) are η', [n_eta, n_eta+n_meta) the meta-opt
            # state, last output the validation loss.  Inputs follow the
            # same leaf order, so out[i] feeds in[i] on the next step.
            "num_eta_leaves": n_eta,
            "num_meta_opt_leaves": n_meta,
            "num_state_leaves": len(state_leaves),
            "meta_lr": meta_lr,
        },
    )
    return art, flat, leaves


def build_toy_artifact(
    num_maps: int,
    variant: str,
    *,
    use_mixed_mode: bool,
    batch: int = 32,
    dim: int = 64,
    inner_updates: int = 2,
    use_loop_fusion: bool = False,
    use_pallas: bool = False,
) -> tuple:
    """§3.2 motivating-example artifact (Fig. 1's x-axis point)."""
    cfg = toy_lib.ToyConfig(
        batch=batch,
        dim=dim,
        num_maps=num_maps,
        inner_updates=inner_updates,
        use_loop_fusion=use_loop_fusion,
        use_mixed_mode=use_mixed_mode,
        use_pallas=use_pallas,
    )
    fn = toy_lib.build_meta_grad(cfg)
    flat, leaves = flatten_fn(fn, toy_lib.example_args(cfg))
    key = f"toy_M{num_maps}_D{dim}_B{batch}_T{inner_updates}_" + (
        "mixflow" if use_mixed_mode else "default"
    ) + ("_pallas" if use_pallas else "")
    art = Artifact(
        key=key,
        kind="toy",
        task="toy",
        variant=variant,
        mode="fwdrev" if use_mixed_mode else "default",
        block_remat=False,
        save_inner_grads=False,
        tier="exec",
        model={"dim": dim, "num_maps": num_maps,
               "param_count": dim * dim,
               "size_name": f"toy{dim}_M{num_maps}"},
        inner_steps=inner_updates,
        batch=batch,
        seq_len=dim,
        vocab_size=0,
        extra={"use_loop_fusion": use_loop_fusion, "use_pallas": use_pallas},
    )
    return art, flat, leaves


# ---------------------------------------------------------------------------
# Grid definitions (DESIGN.md §4)
# ---------------------------------------------------------------------------


def plan(full: bool) -> Dict[str, List[dict]]:
    """Group name → list of builder kwargs (pre-dedup)."""
    groups: Dict[str, List[dict]] = {}

    def mg(task, size, s, b, t, variant, tier, **over):
        base = dict(DEFAULT_VARIANTS[variant]) if variant in DEFAULT_VARIANTS \
            else {}
        base.update(over)
        return dict(
            builder="meta_grad", task_name=task, size=size, seq_len=s,
            batch=b, inner_steps=t, variant=variant, tier=tier, **base,
        )

    # --- fig1: toy example, sweep M, default vs mixed -------------------
    ms = [1, 2, 4, 8, 16, 32] + ([64] if full else [])
    groups["fig1_toy"] = [
        dict(builder="toy", num_maps=m, variant=v,
             use_mixed_mode=(v == "mixflow"))
        for m in ms
        for v in ("default", "mixflow")
    ]

    # --- table3 (+fig2/fig3-at-44M): ablation cube on the 44M rung ------
    groups["table3_ablation"] = [
        mg("maml", "44M", 64, 2, 2,
           variant=f"{m}_br{int(br)}_sg{int(sg)}", tier="exec",
           mode=m, block_remat=br, save_inner_grads=sg)
        for m in ("default", "fwdrev")
        for br in (False, True)
        for sg in (False, True)
    ]

    # --- table2 (+fig3/fig10): ablation cube on the 489M rung -----------
    groups["table2_ablation"] = [
        mg("maml", "489M", 64, 2, 2,
           variant=f"{m}_br{int(br)}_sg{int(sg)}", tier="analysis",
           mode=m, block_remat=br, save_inner_grads=sg)
        for m in ("default", "fwdrev")
        for br in (False, True)
        for sg in (False, True)
    ]

    # --- fig4: joint sweep over tasks × size × T × S (Table 1 scaled) ---
    sizes4 = ["tiny", "small"]
    ts4 = [2, 4] + ([8] if full else [])
    ss4 = [32, 64] + ([128] if full else [])
    groups["fig4_sweep"] = [
        mg(task, size, s, 2, t, variant=v, tier="exec")
        for task in tasks_lib.TASK_NAMES
        for size in sizes4
        for t in ts4
        for s in ss4
        for v in ("default", "mixflow")
    ]

    # --- fig5/fig11: data regimes, per-axis sweeps around a base --------
    base = dict(task="maml", size="small", s=64, b=2, t=2)
    fig5: List[dict] = []
    for size in ["tiny", "small", "44M"] + (["90M"] if full else []):
        tier = "exec" if size in ("tiny", "small") else "analysis"
        fig5.append((dict(base, size=size), tier))
    for s in [32, 64, 128, 256] + ([512] if full else []):
        fig5.append((dict(base, s=s), "exec" if s <= 128 else "analysis"))
    for t in [2, 4, 8]:
        fig5.append((dict(base, t=t), "exec"))
    for b in [1, 2, 4] + ([8] if full else []):
        fig5.append((dict(base, b=b), "exec"))
    groups["fig5_data"] = [
        mg(p["task"], p["size"], p["s"], p["b"], p["t"], variant=v, tier=tier)
        for (p, tier) in fig5
        for v in ("default", "mixflow")
    ]

    # --- fig6: transformer-component sweeps (Table 5 scaled) ------------
    comp_base = dict(d_model=64, ffw_size=256, kv_size=8, n_heads=8,
                     n_layers=4)
    axes = {
        "d_model": [32, 64, 96, 128],
        "ffw_size": [128, 256, 512, 1024],
        "n_heads": [2, 4, 8, 16],
        "n_layers": [2, 4, 8, 16],
    }
    fig6: List[dict] = []
    for axis, values in axes.items():
        for val in values:
            preset = dict(comp_base)
            preset[axis] = val
            name = f"comp_{axis}{val}"
            SIZES[name] = preset
            fig6.extend(
                mg("maml", name, 64, 2, 2, variant=v, tier="analysis")
                for v in ("default", "mixflow")
            )
    groups["fig6_components"] = fig6

    # --- fig7/fig8: the Chinchilla scaling ladder (B=4, T=2, paper §A.9)
    rungs = ["44M", "90M", "140M", "196M", "278M", "489M"] + (
        ["587M", "1018M"] if full else ["587M"]
    )
    groups["fig7_ladder"] = [
        mg("maml", r, 64, 4, 2, variant=v, tier="analysis")
        for r in rungs
        for v in ("default", "mixflow")
    ]

    # --- kernelized pair: L1 Pallas kernels through the full stack ------
    groups["kernelized"] = [
        mg("maml", "tiny", 32, 2, 2, variant=v, tier="exec",
           use_pallas=True)
        for v in ("default", "mixflow")
    ]

    # --- e2e train steps (the Rust meta-training driver's artifacts) ----
    groups["e2e"] = [
        dict(builder="train_step", task_name=task, size="tiny", seq_len=32,
             batch=4, inner_steps=2, variant="mixflow",
             **DEFAULT_VARIANTS["mixflow"])
        for task in tasks_lib.TASK_NAMES
    ]

    return groups


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

#: exec-tier artifacts additionally compiled in-process to record XLA's
#: CompiledMemoryStats (cross-validates the Rust simulator).  Keep small:
#: each compile costs ~10-60 s.  (table3's stats were recorded in the
#: validation pass — see EXPERIMENTS.md — and cost ~8 min of XLA compiles,
#: so they are opt-in via MIXFLOW_AOT_STATS=table3_ablation.)
STATS_GROUPS = tuple(
    ["fig1_toy"]
    + os.environ.get("MIXFLOW_AOT_STATS", "").split(",")
)


def generate(out_dir: str, full: bool, force: bool,
             only_groups: Sequence[str] | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    groups = plan(full)
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest: Dict[str, Any] = {
        "jax_version": jax.__version__,
        "generated_unix": int(time.time()),
        "full": full,
        "artifacts": {},
        "groups": {},
    }
    # Merge an existing manifest so --groups regenerates incrementally.
    # (--force re-lowers files but must never discard other groups'
    # entries — it applies to the selected groups only.)
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        manifest["artifacts"] = old.get("artifacts", {})
        manifest["groups"] = old.get("groups", {})

    for gname, entries in groups.items():
        if only_groups and gname not in only_groups:
            continue
        keys: List[str] = []
        for kwargs in entries:
            builder = kwargs.pop("builder")
            if builder == "toy":
                art, flat, leaves = build_toy_artifact(**kwargs)
            elif builder == "train_step":
                art, flat, leaves = build_train_step_artifact(
                    out_dir=out_dir, **kwargs
                )
            else:
                art, flat, leaves = build_meta_grad_artifact(**kwargs)
            keys.append(art.key)
            hlo_path = os.path.join(out_dir, art.key + ".hlo.txt")
            if (
                art.key in manifest["artifacts"]
                and os.path.exists(hlo_path)
                and not force
            ):
                continue
            t0 = time.time()
            # keep_unused: the Rust runtime feeds every manifest input, so
            # jax must not prune arguments the task ignores (MAML never
            # reads θ₀ — it would otherwise vanish from the entry layout).
            lowered = jax.jit(flat, keep_unused=True).lower(*leaves)
            hlo = to_hlo_text(lowered)
            with open(hlo_path, "w") as f:
                f.write(hlo)
            art.file = os.path.basename(hlo_path)
            art.lower_seconds = round(time.time() - t0, 2)
            art.inputs = [
                {"shape": list(l.shape), "dtype": _dtype_name(l.dtype)}
                for l in leaves
            ]
            out_shapes = jax.eval_shape(flat, *leaves)
            art.outputs = [
                {"shape": list(o.shape), "dtype": _dtype_name(o.dtype)}
                for o in out_shapes
            ]
            try:
                cost = lowered.cost_analysis() or {}
                art.cost = {
                    "flops": float(cost.get("flops", 0.0)),
                    "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
                }
            except Exception:  # pragma: no cover - backend specific
                art.cost = None
            if gname in STATS_GROUPS:
                compiled = lowered.compile()
                ma = compiled.memory_analysis()
                art.xla_stats = {
                    "temp_bytes": int(ma.temp_size_in_bytes),
                    "argument_bytes": int(ma.argument_size_in_bytes),
                    "output_bytes": int(ma.output_size_in_bytes),
                    "alias_bytes": int(ma.alias_size_in_bytes),
                }
            manifest["artifacts"][art.key] = dataclasses.asdict(art)
            print(
                f"[aot] {gname}: {art.key} "
                f"({len(hlo) / 1e6:.2f} MB, {art.lower_seconds}s)",
                flush=True,
            )
        manifest["groups"][gname] = keys
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--full", action="store_true",
                   help="expanded grids (slower)")
    p.add_argument("--force", action="store_true",
                   help="regenerate even if files exist")
    p.add_argument("--groups", default=None,
                   help="comma-separated subset of groups")
    args = p.parse_args()
    only = args.groups.split(",") if args.groups else None
    manifest = generate(args.out, args.full, args.force, only)
    n = len(manifest["artifacts"])
    print(f"[aot] manifest: {n} artifacts in {args.out}")


if __name__ == "__main__":
    main()

"""Layer-2 model: Chinchilla-family transformer in pure JAX pytrees.

Mirrors the paper's §5 inner model: pre-LN residual blocks, multi-head
attention with RoPE (Su et al., 2024), GELU MLP, tied embeddings, and the
next-token-prediction loss.  Parameters are nested dicts so the inner
optimiser, the MixFlow-MG transforms, and the per-parameter meta-tasks all
operate with ``jax.tree`` utilities.

Block rematerialisation (paper §4 optimisation 1) is a config flag: each
residual block is wrapped in ``jax.checkpoint``, which under MixFlow-MG's
forward-over-reverse outer mode costs no extra outer-level checkpoints —
that interaction is the source of the Fig. 3 block-#3 reduction.

The attention / layernorm cores call the L1 Pallas kernels (``use_pallas``)
or the pure-jnp references; both lower into the same AOT HLO artifact.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref as kref
from .kernels import wrappers as kw

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    """Chinchilla-style architecture hyperparameters (paper Tables 5/6)."""

    vocab_size: int = 256
    d_model: int = 128
    ffw_size: int = 512
    kv_size: int = 32          # per-head dim, Chinchilla's `kv_size`
    n_heads: int = 4
    n_layers: int = 2
    seq_len: int = 64
    block_remat: bool = True   # paper §4 optimisation 1
    use_pallas: bool = True    # L1 kernels vs pure-jnp reference cores
    dtype: Any = jnp.float32

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.kv_size

    def param_count(self) -> int:
        """Exact parameter count of :func:`init_params` for this config."""
        c = self
        per_block = (
            4 * c.d_model                                  # 2x LN gamma/beta
            + 3 * c.d_model * c.attn_dim                   # wq wk wv
            + c.attn_dim * c.d_model                       # wo
            + c.d_model * c.ffw_size + c.ffw_size          # w1 b1
            + c.ffw_size * c.d_model + c.d_model           # w2 b2
        )
        return (
            c.vocab_size * c.d_model                       # embed (tied)
            + c.n_layers * per_block
            + 2 * c.d_model                                # final LN
        )


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------


def init_params(rng: jax.Array, cfg: TransformerConfig) -> Params:
    """He/Glorot-style init matching the paper's Chinchilla recipe."""
    keys = jax.random.split(rng, cfg.n_layers + 1)

    def dense(key, fan_in, shape):
        return (
            jax.random.normal(key, shape, cfg.dtype) / math.sqrt(fan_in)
        )

    def block(key) -> Params:
        ks = jax.random.split(key, 6)
        d, a, f = cfg.d_model, cfg.attn_dim, cfg.ffw_size
        return {
            "ln1_g": jnp.ones((d,), cfg.dtype),
            "ln1_b": jnp.zeros((d,), cfg.dtype),
            "wq": dense(ks[0], d, (d, a)),
            "wk": dense(ks[1], d, (d, a)),
            "wv": dense(ks[2], d, (d, a)),
            "wo": dense(ks[3], a, (a, d)),
            "ln2_g": jnp.ones((d,), cfg.dtype),
            "ln2_b": jnp.zeros((d,), cfg.dtype),
            "w1": dense(ks[4], d, (d, f)),
            "b1": jnp.zeros((f,), cfg.dtype),
            "w2": dense(ks[5], f, (f, d)),
            "b2": jnp.zeros((d,), cfg.dtype),
        }

    return {
        "embed": jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), cfg.dtype
        )
        * 0.02,
        "blocks": [block(keys[i + 1]) for i in range(cfg.n_layers)],
        "lnf_g": jnp.ones((cfg.d_model,), cfg.dtype),
        "lnf_b": jnp.zeros((cfg.d_model,), cfg.dtype),
    }


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _rope_tables(seq_len: int, dim: int):
    """RoPE cos/sin tables ``[S, dim/2]`` (Su et al., 2024).

    Computed in host numpy (and cached) so the tables enter every trace as
    fresh constants — caching traced ``jnp`` arrays would leak tracers under
    ``jax.checkpoint``.
    """
    half = dim // 2
    freqs = 1.0 / (10000.0 ** (np.arange(half, dtype=np.float32) / half))
    pos = np.arange(seq_len, dtype=np.float32)
    angles = pos[:, None] * freqs[None, :]
    return np.cos(angles), np.sin(angles)


def apply_rope(x: jax.Array) -> jax.Array:
    """Rotary position embedding over ``[B, H, S, D]`` (D even)."""
    *_, s, d = x.shape
    cos_np, sin_np = _rope_tables(s, d)
    cos, sin = jnp.asarray(cos_np), jnp.asarray(sin_np)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    ).astype(x.dtype)


def _layernorm(x, g, b, cfg: TransformerConfig):
    return (kw.layernorm if cfg.use_pallas else kref.layernorm)(x, g, b)


def _attention_core(q, k, v, cfg: TransformerConfig):
    return (kw.causal_attention if cfg.use_pallas else kref.causal_attention)(
        q, k, v
    )


def _block_fn(p: Params, h: jax.Array, cfg: TransformerConfig) -> jax.Array:
    """One pre-LN residual block: ``h + attn(LN(h)) + mlp(LN(·))``."""
    b, s, d = h.shape
    nh, hd = cfg.n_heads, cfg.kv_size

    x = _layernorm(h, p["ln1_g"], p["ln1_b"], cfg)
    q = (x @ p["wq"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    q, k = apply_rope(q), apply_rope(k)
    attn = _attention_core(q, k, v, cfg)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    h = h + attn @ p["wo"]

    x = _layernorm(h, p["ln2_g"], p["ln2_b"], cfg)
    y = jax.nn.gelu(x @ p["w1"] + p["b1"])
    return h + y @ p["w2"] + p["b2"]


def forward(params: Params, tokens: jax.Array, cfg: TransformerConfig) -> jax.Array:
    """Logits ``[B, S, V]`` for int32 ``tokens [B, S]``."""
    h = jnp.take(params["embed"], tokens, axis=0)
    block = _block_fn
    if cfg.block_remat:
        block = jax.checkpoint(
            functools.partial(_block_fn, cfg=cfg), static_argnums=()
        )
        for p in params["blocks"]:
            h = block(p, h)
    else:
        for p in params["blocks"]:
            h = _block_fn(p, h, cfg)
    h = _layernorm(h, params["lnf_g"], params["lnf_b"], cfg)
    return h @ params["embed"].T  # tied unembedding


def ntp_loss(
    params: Params,
    tokens: jax.Array,
    cfg: TransformerConfig,
    weights: jax.Array | None = None,
) -> jax.Array:
    """Next-token-prediction loss over ``tokens [B, S+1]``.

    ``weights`` (``[B]``, optional) are the per-example factors the
    loss-weighting meta-task produces (paper §5.2, Hu et al. 2023).
    """
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(params, inputs, cfg).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    per_example = -jnp.mean(ll, axis=-1)  # [B]
    if weights is not None:
        per_example = per_example * weights
    return jnp.mean(per_example)


# ---------------------------------------------------------------------------
# The scaled Chinchilla ladder (paper Table 6, proportions preserved)
# ---------------------------------------------------------------------------

#: name -> (d_model, ffw_size, kv_size, n_heads, n_layers); all dims are the
#: paper's Table 6 divided by 8 (d_model/ffw/kv) with layer counts kept,
#: which preserves Eq. 12's L-dependence while fitting CPU budgets.
CHINCHILLA_LADDER = {
    "44M": (64, 256, 8, 8, 8),
    "90M": (80, 320, 8, 10, 13),
    "140M": (96, 384, 8, 12, 15),
    "196M": (112, 448, 8, 14, 16),
    "278M": (128, 512, 8, 16, 18),
    "489M": (160, 640, 16, 10, 21),
    "587M": (176, 704, 16, 11, 21),
    "1018M": (224, 896, 16, 14, 23),
}


def ladder_config(
    name: str,
    seq_len: int = 64,
    vocab_size: int = 256,
    **overrides,
) -> TransformerConfig:
    """Config for a scaled Table-6 ladder rung (see DESIGN.md §2)."""
    d, f, kv, h, l = CHINCHILLA_LADDER[name]
    return TransformerConfig(
        vocab_size=vocab_size,
        d_model=d,
        ffw_size=f,
        kv_size=kv,
        n_heads=h,
        n_layers=l,
        seq_len=seq_len,
        **overrides,
    )

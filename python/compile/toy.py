"""The paper's §3.2 motivating example (Listing 4, Eq. 9).

A deliberately simple BLO problem that isolates the activation-storage
asymmetry between reverse-over-reverse and mixed-mode differentiation:
``η = θ₀`` (MAML-like), L2 inner loss, stateless SGD inner update, and an
inner model that is an ``M``-step elementwise recursive map — so the
computational graph (and therefore the default implementation's stored
activations) grows linearly in ``M`` while the mixed-mode version streams.

``use_loop_fusion=False`` reproduces the paper's "disable loop fusions"
setting by unrolling the map in Python (each of the ``M`` steps is a
distinct HLO region the compiler cannot collapse into a loop); ``True``
uses ``lax.scan``.  ``use_pallas`` swaps the map body for the L1 kernel.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import mixflow
from .kernels import ref as kref
from .kernels import wrappers as kw


@dataclasses.dataclass(frozen=True)
class ToyConfig:
    """Motivating-example hyperparameters (paper used B=1024, D=4096)."""

    batch: int = 64          # B
    dim: int = 128           # D  (θ ∈ R^{D×D}, x ∈ R^{B×D})
    num_maps: int = 8        # M — the swept x-axis of Fig. 1
    inner_updates: int = 2   # T
    inner_lr: float = 1e-3
    use_loop_fusion: bool = False
    use_mixed_mode: bool = True
    use_pallas: bool = False


def apply_model(params: jax.Array, x: jax.Array, cfg: ToyConfig) -> jax.Array:
    """``y_M`` of Eq. (9): ``y₀ = xθ`` then M recursive map steps."""
    y = jnp.matmul(x, params)
    if cfg.use_pallas:
        return kw.toy_map(cfg.num_maps)(y)
    if cfg.use_loop_fusion:

        def f(y, i):
            return i * (2.0 + jnp.sin(y)) ** jnp.cos(y), ()

        y, _ = jax.lax.scan(
            f, y, jnp.arange(1, cfg.num_maps + 1, dtype=y.dtype)
        )
        return y
    return kref.toy_map(y, cfg.num_maps)


def loss(params, x, target, cfg: ToyConfig) -> jax.Array:
    """Standard L2 loss, independent of η (paper §3.2)."""
    return jnp.mean((apply_model(params, x, cfg) - target) ** 2)


def build_meta_grad(cfg: ToyConfig):
    """``∂(meta_loss)/∂θ₀`` exactly as in the paper's Listing 4.

    Returns ``f(params, xs, targets, val_x, val_target) -> meta_grad`` with
    ``xs, targets: [T, B, D]``.
    """
    loss_fn = functools.partial(loss, cfg=cfg)

    def meta_loss(params, xs, targets, val_x, val_target):
        if cfg.use_mixed_mode:
            grad_fn = mixflow.get_fwdrev_grad_fn(loss_fn)
        else:
            grad_fn = jax.grad(loss_fn)

        def inner_step(params, x_and_target):
            d_params = grad_fn(params, *x_and_target)
            params = jax.tree.map(
                lambda p, dp: p - cfg.inner_lr * dp, params, d_params
            )
            return params, ()

        params, _ = jax.lax.scan(inner_step, params, (xs, targets))
        return loss_fn(params, val_x, val_target)

    return jax.grad(meta_loss)


def example_args(cfg: ToyConfig, seed: int = 0) -> Tuple[jax.Array, ...]:
    """Random inputs matching Listing 4's shapes."""
    rng1, rng2, rng3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = jax.random.normal(rng1, (cfg.dim, cfg.dim)) * 0.1
    xs, targets = jax.random.normal(
        rng2, (2, cfg.inner_updates, cfg.batch, cfg.dim)
    )
    val_x, val_target = jax.random.normal(rng3, (2, cfg.batch, cfg.dim))
    return params, xs, targets, val_x, val_target

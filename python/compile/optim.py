"""Differentiable inner-loop optimisers (the state ``υ`` of paper Eq. 3).

Every update is a pure pytree function, so the whole inner optimisation is
differentiable with respect to both ``θ`` and the meta-parameters ``η`` —
the requirement for the update ``Φ`` (and the reparameterised ``Υ``) in
Eqs. (3)–(4).  Adam is the paper's inner optimiser (§5); SGD and momentum
exist for the toy example and ablations.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
OptState = Any
UpdateFn = Callable[[PyTree, OptState, PyTree], Tuple[PyTree, OptState]]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """A (init, update) pair. ``update(grads, state, params) -> (upd, state)``.

    ``upd`` is the *parameter delta* (to be added), so meta-tasks can rescale
    it per-parameter (the hyperparameter-learning task of §5.2) before
    applying it.
    """

    name: str
    init: Callable[[PyTree], OptState]
    update: UpdateFn


def sgd(lr: float = 1e-2) -> Optimizer:
    """Stateless gradient descent (the toy example's inner update)."""

    def init(params):
        del params
        return ()

    def update(grads, state, params):
        del params
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer("sgd", init, update)


def momentum(lr: float = 1e-2, beta: float = 0.9) -> Optimizer:
    """Classical momentum; state is the velocity pytree."""

    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, vel, params):
        del params
        vel = jax.tree.map(lambda v, g: beta * v + g, vel, grads)
        return jax.tree.map(lambda v: -lr * v, vel), vel

    return Optimizer("momentum", init, update)


def adam(
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Optimizer:
    """Adam (Kingma, 2014) — the paper's inner optimiser.

    State is ``(m, v, t)``; the bias-corrected step is fully differentiable
    (``t`` is traced as f32 so the correction participates in the graph).

    Higher-order-AD note: the usual ``m̂/(√v̂ + ε)`` has an ``inf·0``
    second-derivative path at ``v̂ = 0`` (``d√v/dv → ∞``).  Fresh XLA
    algebraically eliminates the dead branch; the pinned 0.5.1 backend the
    Rust runtime uses does not, so the meta-gradient would NaN.  We use
    ``m̂/√(v̂ + ε²)`` — finite derivatives of every order, numerically
    within ε of the classic form.
    """

    def init(params):
        zeros = lambda: jax.tree.map(jnp.zeros_like, params)
        return {"m": zeros(), "v": zeros(), "t": jnp.zeros((), jnp.float32)}

    def update(grads, state, params):
        del params
        t = state["t"] + 1.0
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state["v"], grads
        )
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t
        upd = jax.tree.map(
            lambda m, v: -lr * (m / bc1) / jnp.sqrt(v / bc2 + eps * eps),
            m,
            v,
        )
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer("adam", init, update)


BUILDERS = {"sgd": sgd, "momentum": momentum, "adam": adam}


def by_name(name: str, lr: float) -> Optimizer:
    """Look up an optimiser builder by name (CLI/manifest plumbing)."""
    return BUILDERS[name](lr)

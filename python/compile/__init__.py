"""Build-time compile path: L2 JAX model + L1 Pallas kernels + AOT lowering.

Never imported at runtime — the Rust coordinator consumes only the HLO text
artifacts and ``manifest.json`` that ``compile.aot`` emits.
"""

"""L1 kernels vs pure-jnp oracles, incl. hypothesis shape/dtype sweeps.

DESIGN.md §6: the Pallas kernels must match ``ref.py`` on the primal, the
JVP, the gradient, and the grad-of-grad paths — MixFlow-MG differentiates
through them twice in both modes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, layernorm, ref, toy_map, wrappers

jax.config.update("jax_enable_x64", False)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5
    )


def _assert_close(a, b, dtype=jnp.float32, scale=1.0):
    t = _tol(dtype)
    np.testing.assert_allclose(
        np.asarray(a, np.float32),
        np.asarray(b, np.float32),
        atol=t["atol"] * scale,
        rtol=t["rtol"] * scale,
    )


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    s=st.sampled_from([4, 8, 16, 32, 48]),
    d=st.sampled_from([4, 8, 16]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, s, d, dtype, seed):
    q, k, v = jax.random.normal(
        jax.random.PRNGKey(seed), (3, b, h, s, d), dtype
    )
    out = attention.causal_attention(q, k, v)
    expect = ref.causal_attention(q, k, v)
    _assert_close(out, expect, dtype)


@pytest.mark.parametrize("block_q,block_kv", [(4, 4), (8, 4), (4, 8), (16, 16)])
def test_attention_block_shapes(block_q, block_kv):
    """Block-size choices change the schedule, never the numbers."""
    q, k, v = jax.random.normal(jax.random.PRNGKey(0), (3, 2, 2, 16, 8))
    base = ref.causal_attention(q, k, v)
    out = attention.causal_attention(
        q, k, v, block_q=block_q, block_kv=block_kv
    )
    _assert_close(out, base)


def test_attention_causality():
    """Future tokens must not influence the past."""
    q, k, v = jax.random.normal(jax.random.PRNGKey(1), (3, 1, 1, 16, 8))
    out1 = attention.causal_attention(q, k, v)
    k2 = k.at[:, :, 12:, :].set(99.0)
    v2 = v.at[:, :, 12:, :].set(-99.0)
    out2 = attention.causal_attention(q, k2, v2)
    _assert_close(out1[:, :, :12], out2[:, :, :12])


def test_attention_grad_and_hvp():
    q, k, v = jax.random.normal(jax.random.PRNGKey(2), (3, 1, 2, 16, 8))
    f = lambda q: jnp.sum(jnp.sin(wrappers.causal_attention(q, k, v)))
    g = lambda q: jnp.sum(jnp.sin(ref.causal_attention(q, k, v)))
    _assert_close(jax.grad(f)(q), jax.grad(g)(q), scale=10)
    t = jax.random.normal(jax.random.PRNGKey(3), q.shape)
    hv_f = jax.jvp(jax.grad(f), (q,), (t,))[1]
    hv_g = jax.jvp(jax.grad(g), (q,), (t,))[1]
    _assert_close(hv_f, hv_g, scale=100)


def test_attention_grad_of_grad():
    """Reverse-over-reverse (Algorithm 1's path) also composes."""
    q, k, v = jax.random.normal(jax.random.PRNGKey(4), (3, 1, 1, 8, 4))
    f = lambda q: jnp.sum(wrappers.causal_attention(q, k, v) ** 2)
    g = lambda q: jnp.sum(ref.causal_attention(q, k, v) ** 2)
    gg_f = jax.grad(lambda q: jnp.sum(jax.grad(f)(q) ** 2))(q)
    gg_g = jax.grad(lambda q: jnp.sum(jax.grad(g)(q) ** 2))(q)
    _assert_close(gg_f, gg_g, scale=100)


def test_attention_vmem_estimate_positive_and_monotone():
    small = attention.vmem_bytes_estimate(64, 8)
    big = attention.vmem_bytes_estimate(512, 64)
    assert 0 < small < big
    # Must fit TPU VMEM (16 MiB) for every config we ship (DESIGN.md §7).
    assert attention.vmem_bytes_estimate(8192, 128) < 16 * 2**20


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    rows=st.sampled_from([1, 2, 4, 8, 12, 16]),
    d=st.sampled_from([8, 16, 32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(rows, d, dtype, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(keys[0], (rows, d), dtype) * 3.0
    g = jax.random.normal(keys[1], (d,), dtype)
    b = jax.random.normal(keys[2], (d,), dtype)
    _assert_close(
        layernorm.layernorm(x, g, b), ref.layernorm(x, g, b), dtype
    )


def test_layernorm_3d_and_stats():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16)) * 5 + 3
    out = layernorm.layernorm(x, jnp.ones(16), jnp.zeros(16))
    np.testing.assert_allclose(np.mean(out, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(out, -1), 1.0, atol=1e-2)


def test_layernorm_second_order():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    g, b = jnp.ones(16), jnp.zeros(16)
    f = lambda x: jnp.sum(jnp.cos(wrappers.layernorm(x, g, b)))
    r = lambda x: jnp.sum(jnp.cos(ref.layernorm(x, g, b)))
    t = jnp.ones_like(x)
    _assert_close(
        jax.jvp(jax.grad(f), (x,), (t,))[1],
        jax.jvp(jax.grad(r), (x,), (t,))[1],
        scale=10,
    )


# ---------------------------------------------------------------------------
# Toy map (Eq. 9)
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    rows=st.sampled_from([1, 2, 4, 8]),
    d=st.sampled_from([4, 16, 32]),
    m=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_toy_map_matches_ref(rows, d, m, seed):
    y0 = jax.random.normal(jax.random.PRNGKey(seed), (rows, d)) * 0.3
    _assert_close(
        toy_map.toy_map(y0, m), ref.toy_map(y0, m), scale=m * 10
    )


def test_toy_map_hvp_matches_ref():
    y0 = jax.random.normal(jax.random.PRNGKey(5), (8, 8)) * 0.2
    k = wrappers.toy_map(3)
    f = lambda y: jnp.mean(k(y) ** 2)
    r = lambda y: jnp.mean(ref.toy_map(y, 3) ** 2)
    t = jnp.ones_like(y0)
    _assert_close(
        jax.jvp(jax.grad(f), (y0,), (t,))[1],
        jax.jvp(jax.grad(r), (y0,), (t,))[1],
        scale=100,
    )


def test_toy_map_m1_analytic():
    """M=1: y = 1·(2+sin y₀)^cos(y₀) — check one value by hand."""
    y0 = jnp.zeros((1, 4))
    out = toy_map.toy_map(y0, 1)
    np.testing.assert_allclose(np.asarray(out), 2.0, atol=1e-6)


# ---------------------------------------------------------------------------
# Wrapper machinery itself
# ---------------------------------------------------------------------------


def test_make_differentiable_jvp_uses_ref():
    """The tangent must come from the ref fn, the primal from the kernel."""
    calls = {"kernel": 0, "ref": 0}

    def kernel(x):
        calls["kernel"] += 1
        return x * 2.0

    def reference(x):
        calls["ref"] += 1
        return x * 2.0

    f = wrappers.make_differentiable(kernel, reference)
    x = jnp.ones(3)
    out, tan = jax.jvp(f, (x,), (jnp.ones(3),))
    np.testing.assert_allclose(np.asarray(out), 2.0)
    np.testing.assert_allclose(np.asarray(tan), 2.0)
    assert calls["kernel"] >= 1 and calls["ref"] >= 1

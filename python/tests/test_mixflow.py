"""Correctness of the MixFlow-MG transforms (DESIGN.md §6, item 1).

The paper's central exactness claim: every mode of Proposition 3.1 computes
the *same* meta-gradient as default reverse-over-reverse autodiff — the win
is memory/step-time, never numerics.  These tests pin that down for every
task × mode × ablation-flag combination, plus standalone HVP/MVP checks of
Eqs. (7)–(8) against explicitly-materialised Hessians.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import mixflow, model as model_lib, optim, tasks
from .conftest import tree_allclose


# ---------------------------------------------------------------------------
# HVP/MVP identities on a small analytic problem
# ---------------------------------------------------------------------------


def _quadratic(theta, eta, x):
    """L(θ,η,x) with dense, asymmetric-looking mixed structure."""
    return (
        jnp.sum(jnp.sin(theta) ** 2 * x)
        + jnp.sum(theta * eta) ** 2
        + jnp.sum(jnp.cos(eta) * theta**3)
    )


@pytest.mark.parametrize("mode", ["fwdrev", "revfwd", "revrev"])
def test_hvp_against_dense_hessian(mode):
    n = 5
    theta = jnp.linspace(0.1, 1.0, n)
    eta = jnp.linspace(-0.5, 0.5, n)
    x = jnp.linspace(1.0, 2.0, n)
    ct = jnp.arange(1.0, n + 1)

    grad_fn = mixflow.get_grad_fn(
        lambda th, e: _quadratic(th, e, x), mode
    )
    # Pull the HVP/MVP out via the VJP of the transform.
    _, vjp = jax.vjp(grad_fn, theta, eta)
    hvp_theta, mvp_eta = vjp(ct)

    hess = jax.hessian(lambda th: _quadratic(th, eta, x))(theta)
    mixed = jax.jacobian(
        jax.grad(lambda th, e: _quadratic(th, e, x)), argnums=1
    )(theta, eta)
    np.testing.assert_allclose(hvp_theta, ct @ hess, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(mvp_eta, ct @ mixed, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", ["fwdrev", "revfwd", "revrev"])
def test_transform_primal_equals_grad(mode):
    theta = jnp.array([0.3, -0.7, 1.2])
    eta = jnp.array([0.1, 0.2, 0.3])
    x = jnp.ones(3)
    g_ref = jax.grad(lambda th: _quadratic(th, eta, x))(theta)
    g = mixflow.get_grad_fn(lambda th, e: _quadratic(th, e, x), mode)(
        theta, eta
    )
    np.testing.assert_allclose(g, g_ref, rtol=1e-6)


def test_int_inputs_get_none_cotangents():
    """Token batches (int32) must flow through without cotangents."""

    def loss(p, tokens):
        return jnp.mean(jnp.take(p, tokens, axis=0) ** 2)

    g = mixflow.get_fwdrev_grad_fn(loss)
    p = jnp.ones((8, 4))
    toks = jnp.array([[0, 1], [2, 3]])

    def outer(p):
        d = g(p, toks)
        return jnp.sum((p - 0.1 * d) ** 2)

    got = jax.grad(outer)(p)

    def outer_ref(p):
        d = jax.grad(loss)(p, toks)
        return jnp.sum((p - 0.1 * d) ** 2)

    np.testing.assert_allclose(got, jax.grad(outer_ref)(p), rtol=1e-5)


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        mixflow.get_grad_fn(lambda p: jnp.sum(p), "sideways")
    with pytest.raises(ValueError):
        mixflow.MetaFlags(mode="sideways")


def test_save_grads_requires_checkpoint():
    with pytest.raises(ValueError):
        mixflow.MetaFlags(save_inner_grads=True, per_step_checkpoint=False)


# ---------------------------------------------------------------------------
# Full meta-gradient equivalence across the ablation cube (the core test)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("task_name", tasks.TASK_NAMES)
@pytest.mark.parametrize("mode", ["fwdrev", "revfwd", "revrev"])
@pytest.mark.parametrize("save_grads", [False, True])
def test_meta_grad_matches_default(
    task_name, mode, save_grads, tiny_cfg, tiny_batch
):
    xs, val = tiny_batch
    task = tasks.by_name(task_name, tiny_cfg)
    rng = jax.random.PRNGKey(0)
    eta = task.init_eta(rng)
    theta0 = task.init_theta(jax.random.PRNGKey(1))
    opt0 = task.init_opt_state(theta0)

    base_flags = mixflow.MetaFlags(
        mode="default", save_inner_grads=False, inner_steps=xs.shape[0]
    )
    base = jax.jit(mixflow.build_meta_grad(task, base_flags, with_aux=False))(
        eta, theta0, opt0, xs, val
    )
    flags = mixflow.MetaFlags(
        mode=mode, save_inner_grads=save_grads, inner_steps=xs.shape[0]
    )
    got = jax.jit(mixflow.build_meta_grad(task, flags, with_aux=False))(
        eta, theta0, opt0, xs, val
    )
    assert tree_allclose(base, got) < 1e-4


@pytest.mark.parametrize("task_name", tasks.TASK_NAMES)
def test_meta_grad_without_block_remat_matches(task_name, tiny_batch):
    """Block remat changes memory, never the gradient."""
    xs, val = tiny_batch
    cfg_remat = model_lib.TransformerConfig(
        vocab_size=64, d_model=32, ffw_size=64, kv_size=8, n_heads=2,
        n_layers=2, seq_len=16, use_pallas=False, block_remat=True,
    )
    cfg_norm = dataclass_replace(cfg_remat, block_remat=False)
    grads = []
    for cfg in (cfg_remat, cfg_norm):
        task = tasks.by_name(task_name, cfg)
        eta = task.init_eta(jax.random.PRNGKey(0))
        theta0 = task.init_theta(jax.random.PRNGKey(1))
        opt0 = task.init_opt_state(theta0)
        flags = mixflow.MetaFlags(mode="fwdrev", inner_steps=xs.shape[0])
        grads.append(
            jax.jit(mixflow.build_meta_grad(task, flags, with_aux=False))(
                eta, theta0, opt0, xs, val
            )
        )
    # Rematerialisation recomputes activations in a different fusion
    # order; f32 non-associativity is then amplified by the second-order
    # products, so compare at the gradient's own scale.
    scale = max(
        float(jnp.max(jnp.abs(l))) for l in jax.tree.leaves(grads[0])
    )
    assert tree_allclose(*grads) < max(1e-4, 5e-2 * scale)


def dataclass_replace(cfg, **kw):
    import dataclasses

    return dataclasses.replace(cfg, **kw)


def test_meta_grad_with_aux_returns_val_loss(tiny_cfg, tiny_batch):
    xs, val = tiny_batch
    task = tasks.by_name("maml", tiny_cfg)
    eta = task.init_eta(jax.random.PRNGKey(0))
    theta0 = task.init_theta(jax.random.PRNGKey(1))
    opt0 = task.init_opt_state(theta0)
    flags = mixflow.MetaFlags(mode="fwdrev", inner_steps=xs.shape[0])
    g, v = jax.jit(mixflow.build_meta_grad(task, flags))(
        eta, theta0, opt0, xs, val
    )
    loss = mixflow.build_meta_loss(task, flags)(eta, theta0, opt0, xs, val)
    np.testing.assert_allclose(float(v), float(loss), rtol=1e-5)
    assert jax.tree.structure(g) == jax.tree.structure(eta)


def test_meta_train_step_decreases_loss(tiny_cfg):
    """A few outer steps of the full train-step must reduce V (MAML)."""
    task = tasks.by_name("maml", tiny_cfg)
    flags = mixflow.MetaFlags(mode="fwdrev", inner_steps=2)
    meta_opt = optim.adam(3e-3)
    step = jax.jit(mixflow.build_meta_train_step(task, flags, meta_opt))

    rng = jax.random.PRNGKey(0)
    eta = task.init_eta(rng)
    meta_state = meta_opt.init(eta)
    theta0 = task.init_theta(jax.random.PRNGKey(1))
    opt0 = task.init_opt_state(theta0)

    # Deterministic "language": ascending token sequences are learnable.
    def batch(key, b):
        start = jax.random.randint(key, (b, 1), 0, 32)
        ar = jnp.arange(tiny_cfg.seq_len + 1)[None, :]
        return (start + ar) % tiny_cfg.vocab_size

    losses = []
    for i in range(12):
        k = jax.random.PRNGKey(100 + i)
        xs = jnp.stack([batch(jax.random.fold_in(k, j), 2) for j in range(2)])
        valb = batch(jax.random.fold_in(k, 99), 2)
        eta, meta_state, v = step(eta, meta_state, theta0, opt0, xs, valb)
        losses.append(float(v))
    assert losses[-1] < losses[0], losses


# ---------------------------------------------------------------------------
# Checkpoint-policy plumbing
# ---------------------------------------------------------------------------


def test_tag_inner_grads_preserves_values():
    tree = {"a": jnp.ones(3), "b": [jnp.zeros(2)]}
    tagged = mixflow.tag_inner_grads(tree)
    assert tree_allclose(tree, tagged) == 0.0


def test_checkpoint_inner_step_grad_unchanged():
    def step(carry, x):
        return carry * jnp.cos(x) + x, ()

    def run(step_fn):
        def loss(c0, xs):
            c, _ = jax.lax.scan(step_fn, c0, xs)
            return jnp.sum(c)

        return jax.grad(loss)(jnp.ones(4), jnp.linspace(0, 1, 3))

    base = run(step)
    for sg in (False, True):
        wrapped = mixflow.checkpoint_inner_step(step, sg)
        np.testing.assert_allclose(run(wrapped), base, rtol=1e-6)

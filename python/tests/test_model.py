"""L2 model/optimiser/task tests: shapes, invariances, gradients."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as model_lib, optim, tasks
from .conftest import tree_allclose


# ---------------------------------------------------------------------------
# Transformer
# ---------------------------------------------------------------------------


def test_param_count_matches_init(tiny_cfg):
    params = model_lib.init_params(jax.random.PRNGKey(0), tiny_cfg)
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    assert total == tiny_cfg.param_count()


@settings(max_examples=6, deadline=None)
@given(
    b=st.integers(1, 3),
    s=st.sampled_from([4, 8, 16]),
    layers=st.integers(1, 3),
)
def test_forward_shapes(b, s, layers):
    cfg = model_lib.TransformerConfig(
        vocab_size=32, d_model=16, ffw_size=32, kv_size=4, n_heads=2,
        n_layers=layers, seq_len=s, use_pallas=False,
    )
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((b, s), jnp.int32)
    logits = model_lib.forward(params, toks, cfg)
    assert logits.shape == (b, s, cfg.vocab_size)


def test_forward_causality(tiny_cfg):
    """Changing future tokens must not change past logits."""
    params = model_lib.init_params(jax.random.PRNGKey(0), tiny_cfg)
    s = tiny_cfg.seq_len
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, s), 0, 64)
    toks2 = toks.at[0, s - 1].set((toks[0, s - 1] + 1) % 64)
    l1 = model_lib.forward(params, toks, tiny_cfg)
    l2 = model_lib.forward(params, toks2, tiny_cfg)
    np.testing.assert_allclose(l1[:, : s - 1], l2[:, : s - 1], atol=1e-5)


def test_block_remat_same_loss_and_grad(tiny_cfg, tiny_batch):
    xs, _ = tiny_batch
    batch = xs[0]
    cfg_no = dataclasses.replace(tiny_cfg, block_remat=False)
    params = model_lib.init_params(jax.random.PRNGKey(0), tiny_cfg)
    l_remat, g_remat = jax.value_and_grad(model_lib.ntp_loss)(
        params, batch, tiny_cfg
    )
    l_no, g_no = jax.value_and_grad(model_lib.ntp_loss)(params, batch, cfg_no)
    np.testing.assert_allclose(float(l_remat), float(l_no), rtol=1e-5)
    assert tree_allclose(g_remat, g_no) < 1e-4


def test_pallas_and_ref_model_agree(tiny_batch):
    """The whole transformer with L1 kernels == with jnp reference cores."""
    xs, _ = tiny_batch
    batch = xs[0]
    base = model_lib.TransformerConfig(
        vocab_size=64, d_model=32, ffw_size=64, kv_size=8, n_heads=2,
        n_layers=2, seq_len=16,
    )
    params = model_lib.init_params(jax.random.PRNGKey(0), base)
    cfg_p = dataclasses.replace(base, use_pallas=True)
    cfg_r = dataclasses.replace(base, use_pallas=False)
    lp = model_lib.ntp_loss(params, batch, cfg_p)
    lr = model_lib.ntp_loss(params, batch, cfg_r)
    np.testing.assert_allclose(float(lp), float(lr), rtol=1e-4)
    gp = jax.grad(model_lib.ntp_loss)(params, batch, cfg_p)
    gr = jax.grad(model_lib.ntp_loss)(params, batch, cfg_r)
    assert tree_allclose(gp, gr) < 1e-3


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 2, 8, 16))
    y = model_lib.apply_rope(x)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-4,
    )


def test_rope_position_zero_identity():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 4, 8))
    y = model_lib.apply_rope(x)
    np.testing.assert_allclose(y[..., 0, :], x[..., 0, :], atol=1e-6)


def test_ntp_loss_weighting(tiny_cfg, tiny_batch):
    xs, _ = tiny_batch
    batch = xs[0]
    params = model_lib.init_params(jax.random.PRNGKey(0), tiny_cfg)
    ones = jnp.ones(batch.shape[0])
    l1 = model_lib.ntp_loss(params, batch, tiny_cfg)
    l2 = model_lib.ntp_loss(params, batch, tiny_cfg, weights=ones)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    l3 = model_lib.ntp_loss(params, batch, tiny_cfg, weights=2 * ones)
    np.testing.assert_allclose(float(l3), 2 * float(l1), rtol=1e-5)


def test_ladder_configs_well_formed():
    for name in model_lib.CHINCHILLA_LADDER:
        cfg = model_lib.ladder_config(name)
        assert cfg.d_model % 2 == 0
        assert cfg.attn_dim == cfg.n_heads * cfg.kv_size
        assert cfg.param_count() > 0


def test_ladder_param_counts_monotone():
    counts = [
        model_lib.ladder_config(n).param_count()
        for n in ("44M", "90M", "140M", "196M", "278M", "489M")
    ]
    assert counts == sorted(counts)


# ---------------------------------------------------------------------------
# Optimisers
# ---------------------------------------------------------------------------


def test_sgd_matches_formula():
    opt = optim.sgd(0.1)
    p = {"w": jnp.ones(3)}
    g = {"w": jnp.full(3, 2.0)}
    upd, _ = opt.update(g, opt.init(p), p)
    np.testing.assert_allclose(upd["w"], -0.2, rtol=1e-6)


def test_momentum_accumulates():
    opt = optim.momentum(1.0, beta=0.5)
    p = {"w": jnp.zeros(1)}
    s = opt.init(p)
    g = {"w": jnp.ones(1)}
    u1, s = opt.update(g, s, p)
    u2, s = opt.update(g, s, p)
    np.testing.assert_allclose(u1["w"], -1.0)
    np.testing.assert_allclose(u2["w"], -1.5)


def test_adam_first_step_is_lr_sized():
    opt = optim.adam(1e-3)
    p = {"w": jnp.zeros(4)}
    s = opt.init(p)
    g = {"w": jnp.array([1.0, -1.0, 10.0, -10.0])}
    upd, s = opt.update(g, s, p)
    # Bias-corrected Adam's first step is ±lr regardless of grad scale.
    np.testing.assert_allclose(
        np.abs(np.asarray(upd["w"])), 1e-3, rtol=1e-4
    )
    assert float(s["t"]) == 1.0


def test_adam_update_is_differentiable():
    opt = optim.adam(1e-2)

    def f(g):
        upd, _ = opt.update({"w": g}, opt.init({"w": g}), {"w": g})
        return jnp.sum(upd["w"] ** 2)

    grad = jax.grad(f)(jnp.array([0.5, -0.5]))
    assert np.all(np.isfinite(np.asarray(grad)))


def test_optim_by_name():
    for name in optim.BUILDERS:
        assert optim.by_name(name, 1e-3).name == name


# ---------------------------------------------------------------------------
# Tasks
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("task_name", tasks.TASK_NAMES)
def test_task_roundtrip(task_name, tiny_cfg, tiny_batch):
    xs, val = tiny_batch
    task = tasks.by_name(task_name, tiny_cfg)
    eta = task.init_eta(jax.random.PRNGKey(0))
    theta0 = task.init_theta(jax.random.PRNGKey(1))
    opt0 = task.init_opt_state(theta0)
    theta = task.theta_init(eta, theta0)
    loss = task.inner_loss(theta, eta, xs[0])
    assert np.isfinite(float(loss))
    g = jax.grad(task.inner_loss)(theta, eta, xs[0])
    theta2, _ = task.apply_update(g, theta, opt0, eta)
    assert jax.tree.structure(theta2) == jax.tree.structure(theta)
    v = task.val_loss(theta2, eta, val)
    assert np.isfinite(float(v))


def test_maml_theta_init_is_eta(tiny_cfg):
    task = tasks.by_name("maml", tiny_cfg)
    eta = task.init_eta(jax.random.PRNGKey(0))
    theta0 = task.init_theta(jax.random.PRNGKey(1))
    assert tree_allclose(task.theta_init(eta, theta0), eta) == 0.0


def test_learning_lr_zero_eta_is_plain_adam(tiny_cfg, tiny_batch):
    """exp(0)=1 ⇒ the learning_lr task reduces to the plain inner opt."""
    xs, _ = tiny_batch
    task = tasks.by_name("learning_lr", tiny_cfg)
    maml = tasks.by_name("maml", tiny_cfg)
    theta = task.init_theta(jax.random.PRNGKey(1))
    opt0 = task.init_opt_state(theta)
    eta = task.init_eta(jax.random.PRNGKey(0))  # zeros
    g = jax.grad(task.inner_loss)(theta, eta, xs[0])
    t1, _ = task.apply_update(g, theta, opt0, eta)
    t2, _ = maml.apply_update(g, theta, opt0, None)
    assert tree_allclose(t1, t2) < 1e-6


def test_loss_weighting_alpha_normalised(tiny_cfg, tiny_batch):
    xs, _ = tiny_batch
    task = tasks.by_name("loss_weighting", tiny_cfg)
    eta = task.init_eta(jax.random.PRNGKey(0))
    theta = task.init_theta(jax.random.PRNGKey(1))
    # inner_loss with weights=1 (fresh eta ≈ uniform) ≈ plain NTP.
    l_w = task.inner_loss(theta, eta, xs[0])
    l_plain = model_lib.ntp_loss(theta, xs[0], tiny_cfg)
    assert abs(float(l_w) - float(l_plain)) / float(l_plain) < 0.5


def test_task_unknown_name_raises(tiny_cfg):
    with pytest.raises(KeyError):
        tasks.by_name("nope", tiny_cfg)

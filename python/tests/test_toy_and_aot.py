"""Motivating-example (§3.2) equivalence + AOT pipeline round-trip."""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, toy


# ---------------------------------------------------------------------------
# Toy example
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fusion", [False, True])
@pytest.mark.parametrize("pallas", [False, True])
def test_toy_mixed_equals_default(fusion, pallas):
    if fusion and pallas:
        pytest.skip("pallas path ignores the fusion flag")
    base = toy.ToyConfig(
        batch=4, dim=8, num_maps=3, use_loop_fusion=fusion,
        use_pallas=pallas, use_mixed_mode=False,
    )
    mixed = toy.ToyConfig(
        batch=4, dim=8, num_maps=3, use_loop_fusion=fusion,
        use_pallas=pallas, use_mixed_mode=True,
    )
    args = toy.example_args(base)
    g0 = toy.build_meta_grad(base)(*args)
    g1 = toy.build_meta_grad(mixed)(*args)
    np.testing.assert_allclose(
        np.asarray(g0), np.asarray(g1), rtol=1e-4, atol=1e-5
    )


def test_toy_apply_model_matches_scan_and_unroll():
    cfg_u = toy.ToyConfig(batch=4, dim=8, num_maps=5, use_loop_fusion=False)
    cfg_s = toy.ToyConfig(batch=4, dim=8, num_maps=5, use_loop_fusion=True)
    params, xs, *_ = toy.example_args(cfg_u)
    x = xs[0]
    yu = toy.apply_model(params, x, cfg_u)
    ys = toy.apply_model(params, x, cfg_s)
    np.testing.assert_allclose(np.asarray(yu), np.asarray(ys), rtol=1e-4)


def test_toy_meta_grad_is_descent_direction():
    cfg = toy.ToyConfig(batch=8, dim=8, num_maps=2)
    args = toy.example_args(cfg)
    g = toy.build_meta_grad(cfg)(*args)

    def meta_loss(p):
        mg = toy.build_meta_grad(cfg)  # noqa — reuse loss via finite diff
        return None

    # Finite-difference check along the gradient direction.
    from compile.mixflow import get_fwdrev_grad_fn  # noqa: F401

    def vloss(p):
        import functools

        loss_fn = functools.partial(toy.loss, cfg=cfg)

        def step(params, xt):
            d = jax.grad(loss_fn)(params, *xt)
            return params - cfg.inner_lr * d, ()

        params, _ = jax.lax.scan(step, p, (args[1], args[2]))
        return loss_fn(params, args[3], args[4])

    p0 = args[0]
    eps = 1e-3
    drop = float(vloss(p0) - vloss(p0 - eps * g / jnp.linalg.norm(g)))
    assert drop > 0.0


# ---------------------------------------------------------------------------
# AOT pipeline (on a fresh temp dir — fast configs only)
# ---------------------------------------------------------------------------


def test_flatten_fn_roundtrip():
    def fn(tree, x):
        return {"out": tree["a"] * 2 + x}

    tree = {"a": jnp.ones((2, 3))}
    x = jnp.zeros((2, 3))
    flat, leaves = aot.flatten_fn(fn, (tree, x))
    assert [tuple(l.shape) for l in leaves] == [(2, 3), (2, 3)]
    out = flat(tree["a"], x)
    assert isinstance(out, tuple) and out[0].shape == (2, 3)


def test_to_hlo_text_parses():
    lowered = jax.jit(lambda x: (jnp.sin(x) @ x,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text and "ENTRY" in text
    assert "f32[4,4]" in text


def test_generate_toy_group(tmp_path):
    """End-to-end: plan → lower → manifest, on the cheapest group."""
    out = str(tmp_path / "arts")
    # Monkey-patch the plan to a single tiny toy pair to keep it fast.
    orig_plan = aot.plan
    try:
        aot.plan = lambda full: {
            "fig1_toy": [
                dict(builder="toy", num_maps=2, variant=v,
                     use_mixed_mode=(v == "mixflow"), batch=4, dim=8)
                for v in ("default", "mixflow")
            ]
        }
        manifest = aot.generate(out, full=False, force=True)
    finally:
        aot.plan = orig_plan
    assert len(manifest["artifacts"]) == 2
    for key, art in manifest["artifacts"].items():
        path = os.path.join(out, art["file"])
        assert os.path.exists(path)
        assert art["inputs"] and art["outputs"]
        assert art["outputs"][0]["shape"] == [8, 8]
        # fig1_toy is a STATS_GROUPS member: XLA memory stats recorded.
        assert art["xla_stats"] is not None
        assert art["xla_stats"]["temp_bytes"] > 0
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert set(on_disk["groups"]["fig1_toy"]) == set(
        manifest["artifacts"]
    )


def test_manifest_incremental_skip(tmp_path):
    out = str(tmp_path / "arts")
    orig_plan = aot.plan
    try:
        aot.plan = lambda full: {
            "g": [dict(builder="toy", num_maps=1, variant="default",
                       use_mixed_mode=False, batch=4, dim=8)]
        }
        m1 = aot.generate(out, full=False, force=True)
        key = next(iter(m1["artifacts"]))
        mtime = os.path.getmtime(
            os.path.join(out, m1["artifacts"][key]["file"])
        )
        m2 = aot.generate(out, full=False, force=False)
        assert os.path.getmtime(
            os.path.join(out, m2["artifacts"][key]["file"])
        ) == mtime
    finally:
        aot.plan = orig_plan


def test_sizes_cover_ladder():
    for name in ("44M", "278M", "489M"):
        assert name in aot.SIZES
    assert set(aot.DEFAULT_VARIANTS) == {"default", "mixflow"}

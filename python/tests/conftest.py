"""Shared fixtures for the build-time test suite."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from compile import model as model_lib


@pytest.fixture(scope="session")
def tiny_cfg() -> model_lib.TransformerConfig:
    """A minimal transformer every correctness test can afford."""
    return model_lib.TransformerConfig(
        vocab_size=64,
        d_model=32,
        ffw_size=64,
        kv_size=8,
        n_heads=2,
        n_layers=2,
        seq_len=16,
        use_pallas=False,
    )


@pytest.fixture(scope="session")
def tiny_batch(tiny_cfg):
    """(xs [T,B,S+1], val [B,S+1]) token batches for the tiny config."""
    rng = jax.random.PRNGKey(7)
    t, b = 2, 2
    xs = jax.random.randint(
        rng, (t, b, tiny_cfg.seq_len + 1), 0, tiny_cfg.vocab_size
    )
    val = jax.random.randint(
        jax.random.PRNGKey(8), (b, tiny_cfg.seq_len + 1), 0,
        tiny_cfg.vocab_size,
    )
    return xs, val


def tree_allclose(a, b, atol=1e-4, rtol=1e-4) -> float:
    """Max leafwise abs difference (also asserts matching structure)."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(la, lb))

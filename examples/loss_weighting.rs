//! Adaptive loss-weighting E2E (paper §5.2 task 3, Hu et al. 2023 scaled):
//! a meta-learned weighting network α(η, x) reweights each example's
//! next-token loss; the mixed-derivative term ∂²L/∂η∂θ of Eq. (8) is dense
//! here, making this the strongest exercise of the MVP path.
//!
//! ```bash
//! cargo run --release --example loss_weighting -- [steps]
//! ```

use anyhow::Result;
use mixflow::meta::MetaTrainer;
use mixflow::runtime::Runtime;
use mixflow::util::stats::human_secs;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let runtime = Runtime::new()?;
    let key = runtime
        .manifest
        .group("e2e")
        .iter()
        .find(|m| m.task == "loss_weighting")
        .map(|m| m.key.clone())
        .expect("e2e loss_weighting artifact missing — rerun make artifacts");

    println!("meta-learning per-datapoint loss weights: {key}");
    let mut trainer = MetaTrainer::new(&runtime, &key, 13);
    let report = trainer.train(steps)?;
    for (i, l) in report.losses.iter().enumerate() {
        if i % (steps / 15).max(1) == 0 || i + 1 == report.losses.len() {
            println!("  step {i:>4}  val_loss {l:.4}");
        }
    }
    let (head, tail) = report.improvement(10);
    println!(
        "\n{} outer steps in {} ({:.2} steps/s); loss {head:.4} → {tail:.4}",
        report.steps,
        human_secs(report.seconds),
        report.steps_per_second
    );
    assert!(tail < head, "meta loss weighting must improve validation loss");
    println!("loss_weighting OK");
    Ok(())
}

//! End-to-end driver (DESIGN.md §E2E): full MAML meta-training on a
//! synthetic token corpus, every outer step executed as one AOT-compiled
//! MixFlow-MG artifact from Rust.  Proves all three layers compose: L1
//! Pallas-lowered kernels inside L2's meta-gradient graph, driven by the
//! L3 loop with Python nowhere on the path.
//!
//! Logs the validation-loss curve (recorded in EXPERIMENTS.md) and fails
//! if the meta-loss does not improve.
//!
//! ```bash
//! cargo run --release --example e2e_meta_train -- [steps]
//! ```

use anyhow::Result;
use mixflow::meta::MetaTrainer;
use mixflow::runtime::Runtime;
use mixflow::util::stats::human_secs;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let runtime = Runtime::new()?;

    let key = runtime
        .manifest
        .group("e2e")
        .iter()
        .find(|m| m.task == "maml")
        .map(|m| m.key.clone())
        .expect("e2e maml artifact missing — rerun make artifacts");
    let loaded = runtime.load(&key)?;
    println!(
        "artifact {key}\n  model: {} params, T={}, B={}, S={}\n  compiled in {}\n",
        loaded.meta.param_count,
        loaded.meta.inner_steps,
        loaded.meta.batch,
        loaded.meta.seq_len,
        human_secs(loaded.compile_seconds),
    );

    let mut trainer = MetaTrainer::new(&runtime, &key, 42);
    let report = trainer.train(steps)?;

    println!("loss curve (every {} steps):", (steps / 25).max(1));
    for (i, l) in report.losses.iter().enumerate() {
        if i % (steps / 25).max(1) == 0 || i + 1 == report.losses.len() {
            let bar = "#".repeat((l * 12.0).min(80.0) as usize);
            println!("  {i:>5}  {l:>8.4}  {bar}");
        }
    }
    let (head, tail) = report.improvement(10);
    println!(
        "\n{} outer steps in {} ({:.2} steps/s)",
        report.steps,
        human_secs(report.seconds),
        report.steps_per_second
    );
    println!("meta val loss: first-10 mean {head:.4} → last-10 mean {tail:.4}");
    assert!(
        tail < head,
        "meta-training must reduce the validation loss ({head:.4} → {tail:.4})"
    );
    println!("e2e_meta_train OK");
    Ok(())
}

//! Hyperparameter learning E2E (paper §5.2 task 1, Bengio 2000 scaled):
//! meta-learn per-parameter learning rates for the inner Adam optimiser.
//! η is a pytree of log-scale multipliers; the entire outer update runs as
//! one MixFlow-MG artifact from Rust.
//!
//! ```bash
//! cargo run --release --example hyperlr -- [steps]
//! ```

use anyhow::Result;
use mixflow::meta::MetaTrainer;
use mixflow::runtime::Runtime;
use mixflow::util::stats::human_secs;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let runtime = Runtime::new()?;
    let key = runtime
        .manifest
        .group("e2e")
        .iter()
        .find(|m| m.task == "learning_lr")
        .map(|m| m.key.clone())
        .expect("e2e learning_lr artifact missing — rerun make artifacts");

    println!("meta-learning per-parameter learning rates: {key}");
    let mut trainer = MetaTrainer::new(&runtime, &key, 7);
    let report = trainer.train(steps)?;
    for (i, l) in report.losses.iter().enumerate() {
        if i % (steps / 15).max(1) == 0 || i + 1 == report.losses.len() {
            println!("  step {i:>4}  val_loss {l:.4}");
        }
    }
    let (head, tail) = report.improvement(10);
    println!(
        "\n{} outer steps in {} ({:.2} steps/s); loss {head:.4} → {tail:.4}",
        report.steps,
        human_secs(report.seconds),
        report.steps_per_second
    );
    assert!(tail < head, "learned LRs must improve the validation loss");
    println!("hyperlr OK");
    Ok(())
}

//! Hyperparameter learning E2E, native edition (paper §5.2 task 1): the
//! same meta-learned per-leaf learning-rate task as `examples/hyperlr.rs`,
//! but every gradient — inner, outer, and the second-order MixFlow-MG
//! products — is computed by the pure-Rust autodiff engine.  No PJRT, no
//! artifacts, no Python toolchain.
//!
//! ```bash
//! cargo run --release --example native_hyperlr -- [steps]
//! ```

use mixflow::meta::{print_train_summary, NativeMetaTrainer, NativeTask};

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    println!("meta-learning per-leaf learning rates (native autodiff)");
    let mut trainer = NativeMetaTrainer::new(NativeTask::HyperLr, 7);
    let report = trainer.train(steps);
    print_train_summary(&report, trainer.last_memory.as_ref());
    println!(
        "learned log-LR multipliers: {:?}",
        trainer
            .eta()
            .iter()
            .map(|e| (e.data[0] * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );
    let (head, tail) = report.improvement(10);
    assert!(tail < head, "learned LRs must improve the validation loss");
    // All those outer steps ran on ONE persistent engine: its last
    // per-run memory report must show warm-arena reuse.
    let mem = trainer.last_memory.expect("memory recorded");
    assert!(
        mem.arena_reuses > 0,
        "persistent engine must recycle buffers across outer steps"
    );
    println!(
        "engine: {} hypergradients on one tape; last step reused {} buffers \
         ({} fresh allocs)",
        trainer.engine().outer_steps(),
        mem.arena_reuses,
        mem.arena_allocs
    );
    println!("native_hyperlr OK");
}

//! Memory analysis tour: regenerate the paper's Figure 2 (device-memory
//! footprint over instruction number) and Figure 9 (graph census) for a
//! default/mixflow artifact pair using the HLO liveness simulator —
//! no execution, pure analysis.
//!
//! ```bash
//! cargo run --release --example memory_analysis -- [artifact_key]
//! ```

use anyhow::Result;
use mixflow::coordinator::report::timeline_plot;
use mixflow::hlo::{parser, MemorySimulator};
use mixflow::runtime::Manifest;
use mixflow::util::stats::human_bytes;
use mixflow::util::table::Table;

fn main() -> Result<()> {
    let manifest = Manifest::discover()?;
    // Default: the Table-3 44M-scaled MAML pair (the paper's Fig. 2/3 model).
    let pick = |variant: &str| {
        manifest
            .group("table3_ablation")
            .into_iter()
            .find(|m| m.mode == variant && m.block_remat && m.save_inner_grads == (variant != "default"))
            .map(|m| m.key.clone())
    };
    let keys: Vec<String> = match std::env::args().nth(1) {
        Some(k) => vec![k],
        None => [pick("default"), pick("fwdrev")]
            .into_iter()
            .flatten()
            .collect(),
    };

    let mut census_rows: Vec<(String, usize, usize, u64)> = Vec::new();
    for key in &keys {
        let meta = manifest.get(key)?;
        let text = std::fs::read_to_string(manifest.hlo_path(meta))?;
        let module = parser::parse_module(&text)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let mem = MemorySimulator::new(&module).run();
        println!(
            "{}",
            timeline_plot(
                &format!(
                    "Figure 2 — {} ({}) memory over instruction number",
                    key, meta.variant
                ),
                &mem.timeline,
                100,
                14,
            )
        );
        println!(
            "  static {} (params {} + constants {} + outputs {}) | peak dynamic {}\n",
            human_bytes(mem.static_bytes()),
            human_bytes(mem.param_bytes),
            human_bytes(mem.const_bytes),
            human_bytes(mem.output_bytes),
            human_bytes(mem.peak_dynamic),
        );
        let census = module.opcode_census();
        let data_ops: usize = ["broadcast", "transpose", "copy", "concatenate", "pad", "slice", "dynamic-slice", "dynamic-update-slice"]
            .iter()
            .filter_map(|op| census.get(*op))
            .sum();
        census_rows.push((
            meta.variant.clone(),
            module.instruction_count(),
            data_ops,
            mem.peak_dynamic,
        ));
    }

    if census_rows.len() == 2 {
        println!("Figure 9 — compiled-graph census (data nodes shrink under mixed mode)");
        let mut t = Table::new(&["variant", "instructions", "data-movement ops", "peak dynamic"])
            .numeric_cols(&[1, 2, 3]);
        for (v, n, d, p) in &census_rows {
            t.row(vec![
                v.clone(),
                n.to_string(),
                d.to_string(),
                human_bytes(*p),
            ]);
        }
        println!("{}", t.render());
    }
    Ok(())
}

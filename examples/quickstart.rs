//! Quickstart: load one MixFlow-MG artifact, execute it on the PJRT CPU
//! client, and compare its memory profile against the default-autodiff
//! twin — the 60-second tour of the whole stack.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use mixflow::coordinator::runner::{ExperimentRunner, RunOptions};
use mixflow::runtime::Runtime;
use mixflow::util::stats::{human_bytes, human_secs};

fn main() -> Result<()> {
    let runtime = Runtime::new()?;
    println!(
        "PJRT platform: {} | manifest: {} artifacts (jax {})\n",
        runtime.platform(),
        runtime.manifest.artifacts.len(),
        runtime.manifest.jax_version
    );

    // The "kernelized" pair runs the full stack: Chinchilla transformer
    // with Pallas attention/layernorm kernels (L1), MixFlow-MG meta
    // gradients (L2), executed from Rust (L3).
    let metas = runtime.manifest.group("kernelized");
    let pairs = runtime.manifest.pairs(&metas);
    let (default_meta, mixflow_meta) =
        pairs.first().expect("kernelized pair missing — rerun make artifacts");

    let runner = ExperimentRunner::new(
        &runtime,
        RunOptions { timing_iters: 3, execute: true, seed: 0 },
    );

    println!("== workload: MAML meta-gradient, tiny Chinchilla, Pallas kernels ==");
    for meta in [default_meta, mixflow_meta] {
        let m = runner.run_one(meta, "quickstart")?;
        println!(
            "{:>8}: peak dynamic {} | static {} | step {}",
            meta.variant,
            human_bytes(m.sim_dynamic_bytes),
            human_bytes(m.sim_static_bytes),
            m.step_seconds.map(human_secs).unwrap_or_else(|| "n/a".into()),
        );
    }

    // Numerics: both variants must produce the same meta-gradient.
    let ld = runtime.load(&default_meta.key)?;
    let lx = runtime.load(&mixflow_meta.key)?;
    let inputs = ld.default_inputs(0)?;
    let od = ld.execute(&inputs)?;
    let ox = lx.execute(&inputs)?;
    let mut max_diff = 0f32;
    for (a, b) in od.iter().zip(ox.iter()) {
        for (x, y) in a.to_vec::<f32>()?.iter().zip(b.to_vec::<f32>()?.iter()) {
            max_diff = max_diff.max((x - y).abs());
        }
    }
    println!("\nmeta-gradient max |default - mixflow| = {max_diff:.3e}");
    assert!(max_diff < 1e-3, "MixFlow-MG must be exact");
    println!("quickstart OK — same gradients, smaller memory.");
    Ok(())
}

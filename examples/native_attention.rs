//! The paper's headline configuration, native edition: meta-learned
//! per-leaf learning rates over a **multi-head, batched** self-attention
//! + layernorm block whose inner loop runs **Adam** — the MixFlow-MG
//! backward sweep carries the adjoint through the optimiser moments
//! `m`/`v`, not just θ, and the per-head projections ride the batched
//! 3-D tape ops.  Every gradient (inner, outer, and the second-order
//! products) is computed by the pure-Rust autodiff engine.  No PJRT, no
//! artifacts, no Python toolchain.
//!
//! ```bash
//! cargo run --release --example native_attention -- [steps]
//! ```

use mixflow::autodiff::{CheckpointPolicy, InnerOptimiser};
use mixflow::meta::{print_train_summary, NativeMetaTrainer, NativeTask};

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    println!(
        "meta-learning per-leaf LRs for 2-head batched attention+layernorm \
         (adam inner)"
    );
    // α₀ starts deliberately small; the meta level must grow the LRs to
    // cut the post-unroll validation loss.  The remat segment is left on
    // `auto`, so the persistent engine resolves K ≈ √T per run; 2 heads
    // over 2-sequence batches exercise the batched 3-D tape ops.
    let mut trainer =
        NativeMetaTrainer::with_unroll(NativeTask::Attention, 7, 6)
            .with_inner_opt(InnerOptimiser::adam())
            .with_remat(CheckpointPolicy::Auto)
            .with_attention_shape(2, 2);
    let report = trainer.train(steps);
    print_train_summary(&report, trainer.last_memory.as_ref());
    println!(
        "learned log-LR multipliers (Wq, Wk, Wv, Wo): {:?}",
        trainer
            .eta()
            .iter()
            .map(|e| (e.data[0] * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );
    let (head, tail) = report.improvement(10);
    assert!(tail < head, "learned LRs must improve the validation loss");
    assert!(
        report.artifact.ends_with("attention/mixflow/adam/auto/h2/b2"),
        "multi-head auto-remat run must label the artifact: {:?}",
        report.artifact
    );
    let mem = trainer.last_memory.expect("memory report recorded");
    assert!(mem.kv_peak_bytes > 0, "K/V projections must be tagged");
    assert!(
        mem.kv_ckpt_alias_bytes > 0,
        "backward sweep must rebuild K/V from checkpoint aliases"
    );
    assert!(
        mem.kv_remat_bytes > 0,
        "auto remat (K = √6 ≈ 2) must rematerialise intra-segment K/V"
    );
    println!("native_attention OK");
}

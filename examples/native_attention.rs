//! The paper's headline configuration, native edition: meta-learned
//! per-leaf learning rates over a single-head self-attention + layernorm
//! block whose inner loop runs **Adam** — the MixFlow-MG backward sweep
//! carries the adjoint through the optimiser moments `m`/`v`, not just θ.
//! Every gradient (inner, outer, and the second-order products) is
//! computed by the pure-Rust autodiff engine.  No PJRT, no artifacts, no
//! Python toolchain.
//!
//! ```bash
//! cargo run --release --example native_attention -- [steps]
//! ```

use mixflow::autodiff::{CheckpointPolicy, InnerOptimiser};
use mixflow::meta::{print_train_summary, NativeMetaTrainer, NativeTask};

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    println!(
        "meta-learning per-leaf LRs for attention+layernorm (adam inner)"
    );
    // α₀ starts deliberately small; the meta level must grow the LRs to
    // cut the post-unroll validation loss.  The remat segment is left on
    // `auto`, so the persistent engine resolves K ≈ √T per run.
    let mut trainer =
        NativeMetaTrainer::with_unroll(NativeTask::Attention, 7, 6)
            .with_inner_opt(InnerOptimiser::adam())
            .with_remat(CheckpointPolicy::Auto);
    let report = trainer.train(steps);
    print_train_summary(&report, trainer.last_memory.as_ref());
    println!(
        "learned log-LR multipliers (Wq, Wk, Wv, Wo): {:?}",
        trainer
            .eta()
            .iter()
            .map(|e| (e.data[0] * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );
    let (head, tail) = report.improvement(10);
    assert!(tail < head, "learned LRs must improve the validation loss");
    assert!(
        report.artifact.ends_with("attention/mixflow/adam/auto"),
        "auto remat must label the run: {:?}",
        report.artifact
    );
    println!("native_attention OK");
}

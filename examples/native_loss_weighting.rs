//! Adaptive loss-weighting E2E, native edition (paper §5.2 task 3): half
//! of every inner training batch carries corrupted labels drawn from a
//! noise cluster; η parametrises a per-example weighting net whose dense
//! mixed term ∂²L/∂η∂θ is exactly what MixFlow-MG's forward-over-reverse
//! sweep computes.  Pure Rust end to end.
//!
//! ```bash
//! cargo run --release --example native_loss_weighting -- [steps]
//! ```

use mixflow::meta::{print_train_summary, NativeMetaTrainer, NativeTask};

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    println!("meta-learning per-example loss weights (native autodiff)");
    let mut trainer = NativeMetaTrainer::new(NativeTask::LossWeighting, 13);
    let report = trainer.train(steps);
    print_train_summary(&report, trainer.last_memory.as_ref());
    let (head, tail) = report.improvement(10);
    assert!(tail < head, "meta loss weighting must improve validation loss");
    println!(
        "engine: {} hypergradients on one persistent tape",
        trainer.engine().outer_steps()
    );
    println!("native_loss_weighting OK");
}
